//! The public LSM database handle.
//!
//! Single-writer (matches Raft apply order), multi-reader-safe for the
//! read paths used by the engines.  All the persistence knobs the paper
//! varies across baselines live in [`Options`]:
//!
//! * `wal_enabled=false` → PASV-style passive persistence (no engine
//!   WAL; durability comes from the consensus log).
//! * `sync` → whether appends `fsync` (the paper's testbed batches, so
//!   the default is OS-buffered with explicit `sync()` points).
//! * `value_mode` is implicit: Nezha engines simply store 13-byte
//!   offsets as values, Original stores full values — the Db does not
//!   care.
//!
//! [`IoStats`] counts every byte the engine writes (WAL, flush,
//! compaction) so the benches can report write amplification directly.

use super::compaction;
use super::memtable::MemTable;
use super::sstable::{Table, TableWriter};
use super::version::{table_path, FileMeta, Version};
use super::wal::Wal;
use super::Value;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncMode {
    /// Buffered writes; caller syncs at commit points.
    OsBuffered,
    /// fsync on every WAL batch (durable per write).
    EveryBatch,
}

#[derive(Clone, Debug)]
pub struct Options {
    pub dir: PathBuf,
    pub wal_enabled: bool,
    pub sync: SyncMode,
    /// Memtable flush trigger.
    pub memtable_bytes: usize,
    /// L0 file-count compaction trigger.
    pub l0_compaction_trigger: usize,
    /// L1 size budget; each deeper level gets 10x.
    pub level_base_bytes: u64,
    /// Compaction output file split size.
    pub output_split_bytes: u64,
    /// Block cache capacity (blocks).
    pub block_cache_blocks: usize,
}

impl Options {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            wal_enabled: true,
            sync: SyncMode::OsBuffered,
            memtable_bytes: 4 << 20,
            l0_compaction_trigger: 4,
            level_base_bytes: 32 << 20,
            output_split_bytes: 8 << 20,
            block_cache_blocks: 1024,
        }
    }
}

/// Byte/op counters for write-amplification accounting (shared with
/// the bench harness via `Arc`).
#[derive(Default, Debug)]
pub struct IoStats {
    pub wal_bytes: AtomicU64,
    pub flush_bytes: AtomicU64,
    pub compact_bytes: AtomicU64,
    pub sst_block_reads: AtomicU64,
    pub cache_hits: AtomicU64,
    pub bloom_negative: AtomicU64,
    pub gets: AtomicU64,
    pub puts: AtomicU64,
    /// ValueLog entries resolved (engine read path; zero for plain Db use).
    pub vlog_reads: AtomicU64,
    /// Payload bytes those resolutions returned.
    pub vlog_read_bytes: AtomicU64,
    /// Readahead-cache segment hits on the ValueLog read path.
    pub readahead_hits: AtomicU64,
    /// Readahead-cache segment loads (misses).
    pub readahead_misses: AtomicU64,
    /// Largest adaptive readahead segment size chosen so far (bytes;
    /// see [`crate::vlog::readahead::segment_bytes_for`]).
    pub readahead_seg_bytes: AtomicU64,
    /// WAL durability barriers ([`Db::sync_wal`] calls that hit a WAL).
    pub log_syncs: AtomicU64,
}

impl IoStats {
    pub fn total_write_bytes(&self) -> u64 {
        self.wal_bytes.load(Ordering::Relaxed)
            + self.flush_bytes.load(Ordering::Relaxed)
            + self.compact_bytes.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> IoStatsSnapshot {
        IoStatsSnapshot {
            wal_bytes: self.wal_bytes.load(Ordering::Relaxed),
            flush_bytes: self.flush_bytes.load(Ordering::Relaxed),
            compact_bytes: self.compact_bytes.load(Ordering::Relaxed),
            sst_block_reads: self.sst_block_reads.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            bloom_negative: self.bloom_negative.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            puts: self.puts.load(Ordering::Relaxed),
            vlog_reads: self.vlog_reads.load(Ordering::Relaxed),
            vlog_read_bytes: self.vlog_read_bytes.load(Ordering::Relaxed),
            readahead_hits: self.readahead_hits.load(Ordering::Relaxed),
            readahead_misses: self.readahead_misses.load(Ordering::Relaxed),
            readahead_seg_bytes: self.readahead_seg_bytes.load(Ordering::Relaxed),
            log_syncs: self.log_syncs.load(Ordering::Relaxed),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct IoStatsSnapshot {
    pub wal_bytes: u64,
    pub flush_bytes: u64,
    pub compact_bytes: u64,
    pub sst_block_reads: u64,
    pub cache_hits: u64,
    pub bloom_negative: u64,
    pub gets: u64,
    pub puts: u64,
    pub vlog_reads: u64,
    pub vlog_read_bytes: u64,
    pub readahead_hits: u64,
    pub readahead_misses: u64,
    pub readahead_seg_bytes: u64,
    pub log_syncs: u64,
}

impl IoStatsSnapshot {
    pub fn total_write_bytes(&self) -> u64 {
        self.wal_bytes + self.flush_bytes + self.compact_bytes
    }
}

/// FIFO-with-reinsertion block cache (approximate LRU; DESIGN.md §2
/// discusses why this is sufficient at bench scale).
pub struct BlockCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    /// Hit counter (mirrored into [`IoStats::cache_hits`] by the Db).
    pub hits: AtomicU64,
}

struct CacheInner {
    map: HashMap<(u64, u64), Arc<Vec<u8>>>,
    queue: VecDeque<(u64, u64)>,
}

impl BlockCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(CacheInner { map: HashMap::new(), queue: VecDeque::new() }),
            capacity: capacity.max(8),
            hits: AtomicU64::new(0),
        }
    }

    pub fn get_or_load(
        &self,
        file: u64,
        block: u64,
        load: impl FnOnce() -> Result<Arc<Vec<u8>>>,
    ) -> Result<Arc<Vec<u8>>> {
        {
            let inner = self.inner.lock().unwrap();
            if let Some(b) = inner.map.get(&(file, block)) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(b));
            }
        }
        let data = load()?;
        let mut inner = self.inner.lock().unwrap();
        if inner.map.len() >= self.capacity {
            while let Some(victim) = inner.queue.pop_front() {
                if inner.map.remove(&victim).is_some() {
                    break;
                }
            }
        }
        inner.map.insert((file, block), Arc::clone(&data));
        inner.queue.push_back((file, block));
        Ok(data)
    }

    pub fn contains(&self, file: u64, block: u64) -> bool {
        self.inner.lock().unwrap().map.contains_key(&(file, block))
    }

    /// Drop every cached block for a dropped file.
    pub fn evict_file(&self, file: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|(f, _), _| *f != file);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub struct Db {
    opts: Options,
    mem: MemTable,
    wal: Option<Wal>,
    version: Version,
    tables: HashMap<u64, Arc<Table>>,
    cache: Arc<BlockCache>,
    stats: Arc<IoStats>,
}

impl Db {
    /// Open (or create) a database at `opts.dir`, replaying any WAL.
    pub fn open(opts: Options) -> Result<Self> {
        std::fs::create_dir_all(&opts.dir)
            .with_context(|| format!("db dir {:?}", opts.dir))?;
        let version = Version::load(&opts.dir)?.unwrap_or_else(Version::new);
        let mut tables = HashMap::new();
        for f in version.live_files() {
            let t = Table::open(f.id, &table_path(&opts.dir, f.id))?;
            tables.insert(f.id, Arc::new(t));
        }
        let mut mem = MemTable::new();
        let wal_path = opts.dir.join("wal.log");
        if opts.wal_enabled {
            Wal::replay(&wal_path, |k, v| mem.insert(k, v))?;
        }
        let wal = if opts.wal_enabled {
            Some(Wal::create(&wal_path)?)
        } else {
            None
        };
        let cache = Arc::new(BlockCache::new(opts.block_cache_blocks));
        Ok(Self {
            opts,
            mem,
            wal,
            version,
            tables,
            cache,
            stats: Arc::new(IoStats::default()),
        })
    }

    pub fn stats(&self) -> Arc<IoStats> {
        Arc::clone(&self.stats)
    }

    pub fn options(&self) -> &Options {
        &self.opts
    }

    pub fn put(&mut self, key: &[u8], value: &[u8]) -> Result<()> {
        self.write(key, Value::Put(value.to_vec()))
    }

    pub fn delete(&mut self, key: &[u8]) -> Result<()> {
        self.write(key, Value::Delete)
    }

    fn write(&mut self, key: &[u8], value: Value) -> Result<()> {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        if let Some(wal) = &mut self.wal {
            let n = wal.append_batch(&[(key, &value)])?;
            self.stats.wal_bytes.fetch_add(n, Ordering::Relaxed);
            if self.opts.sync == SyncMode::EveryBatch {
                wal.sync()?;
            }
        }
        self.mem.insert(key, value);
        if self.mem.approx_bytes() >= self.opts.memtable_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Batched write: one WAL frame for the whole batch.
    pub fn write_batch(&mut self, ops: &[(&[u8], Value)]) -> Result<()> {
        self.stats.puts.fetch_add(ops.len() as u64, Ordering::Relaxed);
        if let Some(wal) = &mut self.wal {
            let refs: Vec<(&[u8], &Value)> = ops.iter().map(|(k, v)| (*k, v)).collect();
            let n = wal.append_batch(&refs)?;
            self.stats.wal_bytes.fetch_add(n, Ordering::Relaxed);
            if self.opts.sync == SyncMode::EveryBatch {
                wal.sync()?;
            }
        }
        for (k, v) in ops {
            self.mem.insert(k, v.clone());
        }
        if self.mem.approx_bytes() >= self.opts.memtable_bytes {
            self.flush()?;
        }
        Ok(())
    }

    /// Force WAL to durable media (group-commit point).
    pub fn sync_wal(&mut self) -> Result<()> {
        if let Some(wal) = &mut self.wal {
            wal.sync()?;
            self.stats.log_syncs.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    pub fn get(&self, key: &[u8]) -> Result<Option<Vec<u8>>> {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        if let Some(v) = self.mem.get(key) {
            return Ok(v.as_put().map(|s| s.to_vec()));
        }
        // L0 newest-first.
        for f in &self.version.levels[0] {
            if let Some(v) = self.table_get(f.id, key)? {
                return Ok(v.as_put().map(|s| s.to_vec()));
            }
        }
        // Deeper levels: at most one file can contain the key.
        for level in 1..self.version.levels.len() {
            let files = &self.version.levels[level];
            let i = files.partition_point(|f| f.last_key.as_slice() < key);
            if i < files.len() && files[i].first_key.as_slice() <= key {
                if let Some(v) = self.table_get(files[i].id, key)? {
                    return Ok(v.as_put().map(|s| s.to_vec()));
                }
            }
        }
        Ok(None)
    }

    fn table_get(&self, id: u64, key: &[u8]) -> Result<Option<Value>> {
        let t = &self.tables[&id];
        if !t.may_contain(key) {
            self.stats.bloom_negative.fetch_add(1, Ordering::Relaxed);
            return Ok(None);
        }
        self.stats.sst_block_reads.fetch_add(1, Ordering::Relaxed);
        let r = t.get(key, Some(&self.cache));
        self.stats
            .cache_hits
            .store(self.cache.hits.load(Ordering::Relaxed), Ordering::Relaxed);
        r
    }

    /// Ordered scan of `[start, end)`, up to `limit` live entries.
    /// An empty `end` means unbounded (scan to the last key).
    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<(Vec<u8>, Vec<u8>)>> {
        use crate::util::key_before_end;
        // Merge oldest→newest so later inserts win, then strip
        // tombstones.
        let mut merged: BTreeMap<Vec<u8>, Value> = BTreeMap::new();
        for level in (1..self.version.levels.len()).rev() {
            for f in &self.version.levels[level] {
                if key_before_end(&f.first_key, end) && start <= f.last_key.as_slice() {
                    for (k, v) in self.tables[&f.id].range(start, end)? {
                        merged.insert(k, v);
                    }
                }
            }
        }
        for f in self.version.levels[0].iter().rev() {
            if key_before_end(&f.first_key, end) && start <= f.last_key.as_slice() {
                for (k, v) in self.tables[&f.id].range(start, end)? {
                    merged.insert(k, v);
                }
            }
        }
        for (k, v) in self.mem.range(start, end) {
            merged.insert(k.to_vec(), v.clone());
        }
        Ok(merged
            .into_iter()
            .filter_map(|(k, v)| match v {
                Value::Put(val) => Some((k, val)),
                Value::Delete => None,
            })
            .take(limit)
            .collect())
    }

    /// Flush the memtable to a new L0 SSTable, then run any triggered
    /// compactions to completion.
    pub fn flush(&mut self) -> Result<()> {
        if self.mem.is_empty() {
            return Ok(());
        }
        let id = self.version.alloc_file_id();
        let path = table_path(&self.opts.dir, id);
        let mut w = TableWriter::create(&path)?;
        for (k, v) in self.mem.iter() {
            w.add(k, v)?;
        }
        let (size, entries) = w.finish()?;
        self.stats.flush_bytes.fetch_add(size, Ordering::Relaxed);
        let t = Table::open(id, &path)?;
        self.version.add_l0(FileMeta {
            id,
            size,
            entries,
            first_key: t.first_key().unwrap_or_default().to_vec(),
            last_key: t.last_key().unwrap_or_default().to_vec(),
        });
        self.tables.insert(id, Arc::new(t));
        self.version.save(&self.opts.dir)?;
        self.mem.clear();
        // WAL content is now durable in the SSTable: start a fresh log.
        if self.opts.wal_enabled {
            let wal_path = self.opts.dir.join("wal.log");
            self.wal = None;
            Wal::remove(&wal_path)?;
            self.wal = Some(Wal::create(&wal_path)?);
        }
        self.maybe_compact()?;
        Ok(())
    }

    fn maybe_compact(&mut self) -> Result<()> {
        while let Some(job) = compaction::pick(
            &self.version,
            self.opts.l0_compaction_trigger,
            self.opts.level_base_bytes,
        ) {
            let (metas, bytes) = compaction::run(
                &self.opts.dir,
                &mut self.version,
                &self.tables,
                &job,
                self.opts.output_split_bytes,
            )?;
            self.stats.compact_bytes.fetch_add(bytes, Ordering::Relaxed);
            for m in &metas {
                let t = Table::open(m.id, &table_path(&self.opts.dir, m.id))?;
                self.tables.insert(m.id, Arc::new(t));
            }
            for id in &job.inputs {
                self.tables.remove(id);
                self.cache.evict_file(*id);
                let _ = std::fs::remove_file(table_path(&self.opts.dir, *id));
            }
            self.version.save(&self.opts.dir)?;
        }
        Ok(())
    }

    pub fn memtable_bytes(&self) -> usize {
        self.mem.approx_bytes()
    }

    pub fn file_count(&self) -> usize {
        self.version.file_count()
    }

    pub fn level_sizes(&self) -> Vec<u64> {
        (0..self.version.levels.len())
            .map(|l| self.version.total_bytes(l))
            .collect()
    }

    /// On-disk footprint of live SSTables (used by recovery + GC sizing
    /// experiments).
    pub fn table_bytes(&self) -> u64 {
        self.version.live_files().map(|f| f.size).sum()
    }

    /// Bulk-ingest a sorted run directly as an SSTable, bypassing WAL +
    /// memtable.  Models LSM-Raft's follower-side SSTable shipping.
    pub fn ingest_sorted(&mut self, entries: &[(Vec<u8>, Vec<u8>)]) -> Result<()> {
        if entries.is_empty() {
            return Ok(());
        }
        let id = self.version.alloc_file_id();
        let path = table_path(&self.opts.dir, id);
        let mut w = TableWriter::create(&path)?;
        for (k, v) in entries {
            w.add(k, &Value::Put(v.clone()))?;
        }
        let (size, n) = w.finish()?;
        self.stats.flush_bytes.fetch_add(size, Ordering::Relaxed);
        let t = Table::open(id, &path)?;
        self.version.add_l0(FileMeta {
            id,
            size,
            entries: n,
            first_key: t.first_key().unwrap_or_default().to_vec(),
            last_key: t.last_key().unwrap_or_default().to_vec(),
        });
        self.tables.insert(id, Arc::new(t));
        self.version.save(&self.opts.dir)?;
        self.maybe_compact()
    }

    /// Destroy all files (test/bench cleanup).
    pub fn destroy(dir: &std::path::Path) -> Result<()> {
        match std::fs::remove_dir_all(dir) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpopts(name: &str) -> Options {
        let dir = std::env::temp_dir().join(format!("nezha-db-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut o = Options::new(dir);
        o.memtable_bytes = 64 << 10;
        o.level_base_bytes = 256 << 10;
        o.output_split_bytes = 64 << 10;
        o
    }

    #[test]
    fn put_get_roundtrip_through_flushes() {
        let mut db = Db::open(tmpopts("rt")).unwrap();
        for i in 0..2000u32 {
            let k = format!("key{i:06}");
            db.put(k.as_bytes(), format!("val{i}").as_bytes()).unwrap();
        }
        assert!(db.file_count() > 0, "expected flushes");
        for i in (0..2000).step_by(37) {
            let k = format!("key{i:06}");
            assert_eq!(db.get(k.as_bytes()).unwrap(), Some(format!("val{i}").into_bytes()), "{k}");
        }
        assert_eq!(db.get(b"missing").unwrap(), None);
    }

    #[test]
    fn overwrites_visible_across_levels() {
        let mut db = Db::open(tmpopts("ow")).unwrap();
        for round in 0..5u32 {
            for i in 0..500u32 {
                let k = format!("key{i:04}");
                db.put(k.as_bytes(), format!("r{round}").as_bytes()).unwrap();
            }
            db.flush().unwrap();
        }
        for i in 0..500u32 {
            let k = format!("key{i:04}");
            assert_eq!(db.get(k.as_bytes()).unwrap(), Some(b"r4".to_vec()));
        }
    }

    #[test]
    fn deletes_mask_older_values() {
        let mut db = Db::open(tmpopts("del")).unwrap();
        db.put(b"a", b"1").unwrap();
        db.flush().unwrap();
        db.delete(b"a").unwrap();
        assert_eq!(db.get(b"a").unwrap(), None);
        db.flush().unwrap();
        assert_eq!(db.get(b"a").unwrap(), None);
        let scan = db.scan(b"", b"zzz", 100).unwrap();
        assert!(scan.is_empty());
    }

    #[test]
    fn wal_replay_recovers_unflushed_writes() {
        let opts = tmpopts("walrec");
        {
            let mut db = Db::open(opts.clone()).unwrap();
            db.put(b"k1", b"v1").unwrap();
            db.put(b"k2", b"v2").unwrap();
            db.sync_wal().unwrap();
            // drop without flush = crash
        }
        let db = Db::open(opts).unwrap();
        assert_eq!(db.get(b"k1").unwrap(), Some(b"v1".to_vec()));
        assert_eq!(db.get(b"k2").unwrap(), Some(b"v2".to_vec()));
    }

    #[test]
    fn no_wal_means_unflushed_writes_lost() {
        let mut opts = tmpopts("nowal");
        opts.wal_enabled = false;
        {
            let mut db = Db::open(opts.clone()).unwrap();
            db.put(b"k1", b"v1").unwrap();
        }
        let db = Db::open(opts).unwrap();
        assert_eq!(db.get(b"k1").unwrap(), None); // PASV semantics
    }

    #[test]
    fn scan_merges_levels_with_newest_wins() {
        let mut db = Db::open(tmpopts("scan")).unwrap();
        for i in 0..100u32 {
            db.put(format!("k{i:03}").as_bytes(), b"old").unwrap();
        }
        db.flush().unwrap();
        for i in (0..100u32).step_by(2) {
            db.put(format!("k{i:03}").as_bytes(), b"new").unwrap();
        }
        let rows = db.scan(b"k000", b"k100", 1000).unwrap();
        assert_eq!(rows.len(), 100);
        for (i, (_, v)) in rows.iter().enumerate() {
            let want: &[u8] = if i % 2 == 0 { b"new" } else { b"old" };
            assert_eq!(v.as_slice(), want, "i={i}");
        }
    }

    #[test]
    fn scan_limit_respected() {
        let mut db = Db::open(tmpopts("limit")).unwrap();
        for i in 0..50u32 {
            db.put(format!("k{i:03}").as_bytes(), b"v").unwrap();
        }
        assert_eq!(db.scan(b"k", b"l", 7).unwrap().len(), 7);
    }

    #[test]
    fn compaction_reduces_file_count_and_preserves_data() {
        let mut opts = tmpopts("compact");
        opts.memtable_bytes = 8 << 10;
        opts.l0_compaction_trigger = 2;
        let mut db = Db::open(opts).unwrap();
        for i in 0..3000u32 {
            db.put(format!("key{i:06}").as_bytes(), &[7u8; 64]).unwrap();
        }
        let stats = db.stats();
        assert!(stats.compact_bytes.load(Ordering::Relaxed) > 0, "compaction ran");
        for i in (0..3000).step_by(101) {
            assert!(db.get(format!("key{i:06}").as_bytes()).unwrap().is_some());
        }
        // L0 held below trigger after compactions settle.
        assert!(db.level_sizes()[0] < db.table_bytes());
    }

    #[test]
    fn write_amplification_visible_in_stats() {
        let mut opts = tmpopts("wa");
        opts.memtable_bytes = 16 << 10;
        opts.l0_compaction_trigger = 2;
        let mut db = Db::open(opts).unwrap();
        let mut user_bytes = 0u64;
        for i in 0..2000u32 {
            let k = format!("key{i:06}");
            let v = [3u8; 128];
            user_bytes += (k.len() + v.len()) as u64;
            db.put(k.as_bytes(), &v).unwrap();
        }
        db.flush().unwrap();
        let s = db.stats().snapshot();
        // WAL + flush alone write everything at least twice.
        let wa = s.total_write_bytes() as f64 / user_bytes as f64;
        assert!(s.total_write_bytes() > user_bytes * 2, "wa={wa:.2}");
    }

    #[test]
    fn ingest_sorted_is_readable() {
        let mut db = Db::open(tmpopts("ingest")).unwrap();
        let entries: Vec<_> = (0..100u32)
            .map(|i| (format!("k{i:04}").into_bytes(), vec![9u8; 32]))
            .collect();
        db.ingest_sorted(&entries).unwrap();
        assert_eq!(db.get(b"k0042").unwrap(), Some(vec![9u8; 32]));
        // No WAL bytes for ingestion.
        assert_eq!(db.stats().snapshot().wal_bytes, 0);
    }

    #[test]
    fn reopen_after_clean_flush() {
        let opts = tmpopts("reopen");
        {
            let mut db = Db::open(opts.clone()).unwrap();
            for i in 0..500u32 {
                db.put(format!("k{i:04}").as_bytes(), b"v").unwrap();
            }
            db.flush().unwrap();
        }
        let db = Db::open(opts).unwrap();
        assert_eq!(db.get(b"k0250").unwrap(), Some(b"v".to_vec()));
        assert_eq!(db.scan(b"k", b"l", 10_000).unwrap().len(), 500);
    }

    #[test]
    fn block_cache_serves_repeat_reads() {
        let mut db = Db::open(tmpopts("cache")).unwrap();
        for i in 0..500u32 {
            db.put(format!("k{i:04}").as_bytes(), &[1u8; 256]).unwrap();
        }
        db.flush().unwrap();
        let _ = db.get(b"k0100").unwrap();
        let before = db.stats().snapshot().cache_hits;
        let _ = db.get(b"k0100").unwrap();
        let _ = db.get(b"k0101").unwrap(); // same block, very likely
        let after = db.stats().snapshot().cache_hits;
        assert!(after >= before, "cache stats move forward");
    }
}
