//! From-scratch LSM-tree storage engine — the RocksDB substitute
//! (DESIGN.md §2).  Reproduces exactly the persistence paths the paper
//! counts when it says a Raft-based KV store writes each value ≥3
//! times: the engine WAL, the memtable→SSTable flush, and the
//! background compaction rewrites.
//!
//! Components:
//! * [`memtable`] — in-memory sorted write buffer with size accounting.
//! * [`wal`] — CRC-framed write-ahead log with replay.
//! * [`bloom`] — per-SSTable Bloom filters.
//! * [`sstable`] — immutable sorted-table writer/reader (data blocks +
//!   index block + bloom + footer).
//! * [`version`] — the level structure (L0 overlap + leveled L1..Ln)
//!   with a rewrite-on-change MANIFEST.
//! * [`compaction`] — leveled compaction picker + k-way merge.
//! * [`db`] — the public [`Db`] handle (put/get/delete/scan/flush).
//!
//! The engine is deliberately synchronous and single-writer: benches
//! drive it from the coordinator's apply loop, mirroring how Raft
//! applies committed entries in order.

pub mod bloom;
pub mod compaction;
pub mod db;
pub mod memtable;
pub mod sstable;
pub mod version;
pub mod wal;

pub use db::{Db, IoStats, Options, SyncMode};

/// A stored value or a tombstone.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Value {
    Put(Vec<u8>),
    Delete,
}

impl Value {
    pub fn as_put(&self) -> Option<&[u8]> {
        match self {
            Value::Put(v) => Some(v),
            Value::Delete => None,
        }
    }

    pub fn encoded_len(&self) -> usize {
        match self {
            Value::Put(v) => v.len(),
            Value::Delete => 0,
        }
    }
}
