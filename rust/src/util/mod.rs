//! Shared substrate utilities: binary codec, CRC framing, deterministic
//! PRNG + Zipf sampling, latency histograms, and the in-repo
//! property-testing harness (proptest is unavailable offline; see
//! DESIGN.md §2).

pub mod codec;
pub mod hist;
pub mod prop;
pub mod rng;

pub use codec::{Decoder, Encoder};
pub use hist::Histogram;
pub use rng::{Rng, Zipf};

/// Monotonic wall-clock helper returning microseconds since an
/// arbitrary epoch (process start).
pub fn now_micros() -> u64 {
    use std::time::Instant;
    static START: once_cell::sync::Lazy<Instant> =
        once_cell::sync::Lazy::new(Instant::now);
    START.elapsed().as_micros() as u64
}
