//! Shared substrate utilities: binary codec, CRC framing, deterministic
//! PRNG + Zipf sampling, latency histograms, and the in-repo
//! property-testing harness (proptest is unavailable offline; see
//! DESIGN.md §2).

pub mod codec;
pub mod hist;
pub mod prop;
pub mod rng;

pub use codec::{Decoder, Encoder};
pub use hist::Histogram;
pub use rng::{Rng, Zipf};

/// Monotonic wall-clock helper returning microseconds since an
/// arbitrary epoch (process start).
pub fn now_micros() -> u64 {
    use std::sync::OnceLock;
    use std::time::Instant;
    static START: OnceLock<Instant> = OnceLock::new();
    START.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// `key < end` with the convention that an **empty** `end` means an
/// unbounded upper range (+∞).  Every scan path uses this so full-range
/// scans (snapshots, recovery dumps) cannot silently drop keys that
/// sort above an arbitrary sentinel like `[0xff; 32]`.
pub fn key_before_end(key: &[u8], end: &[u8]) -> bool {
    end.is_empty() || key < end
}
