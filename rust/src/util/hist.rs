//! Log-bucketed latency histogram (HdrHistogram-style, base-2 with
//! linear sub-buckets) for the benchmark harness: records microsecond
//! samples, reports mean / p50 / p95 / p99 / max with bounded error.

const SUB_BITS: u32 = 5; // 32 linear sub-buckets per power of two (~3% error)
const SUB: usize = 1 << SUB_BITS;
const BUCKETS: usize = 64 - SUB_BITS as usize + 1; // covers the full u64 range

#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    max: u64,
    min: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS * SUB],
            total: 0,
            sum: 0,
            max: 0,
            min: u64::MAX,
        }
    }

    #[inline]
    fn slot(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros();
        let bucket = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> (msb - SUB_BITS)) as usize & (SUB - 1);
        bucket * SUB + sub
    }

    /// Representative (upper-bound) value for a slot.
    fn slot_value(slot: usize) -> u64 {
        let bucket = slot / SUB;
        let sub = slot % SUB;
        if bucket == 0 {
            return sub as u64;
        }
        let shift = bucket as u32 - 1;
        ((SUB + sub) as u64) << shift
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[Self::slot(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn max(&self) -> u64 {
        if self.total == 0 { 0 } else { self.max }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }

    /// Quantile in `[0,1]` -> approximate value (upper bucket bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (slot, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::slot_value(slot).min(self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// One-line summary used by the bench tables (micros in, ms out
    /// where sensible).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={}us p99={}us max={}us",
            self.total,
            self.mean(),
            self.p50(),
            self.p99(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p99(), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn exact_for_small_values() {
        let mut h = Histogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
        assert_eq!(h.quantile(1.0), 31);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.p50() as f64;
        assert!((p50 - 5_000.0).abs() / 5_000.0 < 0.05, "p50={p50}");
        let p99 = h.p99() as f64;
        assert!((p99 - 9_900.0).abs() / 9_900.0 < 0.05, "p99={p99}");
    }

    #[test]
    fn mean_is_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_equals_combined_stream() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            c.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.p50(), c.p50());
        assert_eq!(a.p99(), c.p99());
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn huge_values_do_not_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.min(), 0);
    }
}
