//! Minimal property-based testing harness (proptest is unavailable in
//! the offline sandbox — DESIGN.md §2).
//!
//! Usage:
//! ```ignore
//! prop::check("name", 500, |g| {
//!     let xs: Vec<u8> = g.vec(0..64, |g| g.u8());
//!     // ... assert invariant, or return Err(msg)
//!     Ok(())
//! });
//! ```
//! Each case draws from a seeded generator; on failure the harness
//! panics with the case seed so the exact input is reproducible by
//! running the property once with [`check_one`].

use super::rng::Rng;
use std::ops::Range;

/// Random input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }

    pub fn u8(&mut self) -> u8 {
        self.rng.next_u64() as u8
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        if r.is_empty() {
            return r.start;
        }
        self.rng.range(r.start as u64, r.end as u64) as usize
    }

    pub fn u64_in(&mut self, r: Range<u64>) -> u64 {
        if r.is_empty() {
            return r.start;
        }
        self.rng.range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Random bytes with length drawn from `len`.
    pub fn bytes(&mut self, len: Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len);
        let mut v = vec![0u8; n];
        self.rng.fill(&mut v);
        v
    }

    /// ASCII-ish key (printable, sortable) — nicer failure output than
    /// raw bytes when testing ordered structures.
    pub fn key(&mut self, len: Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len).max(1);
        (0..n).map(|_| b'a' + (self.rng.below(26) as u8)).collect()
    }

    pub fn vec<T>(&mut self, len: Range<usize>, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize_in(len);
        (0..n).map(|_| f(self)).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }
}

/// Run `cases` random cases of `prop`. Panics with the failing seed.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    // Base seed is fixed for reproducible CI; mix the name in so
    // distinct properties see distinct streams.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    for i in 0..cases {
        let seed = h ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed (case {i}, seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single case by seed (for debugging a reported failure).
pub fn check_one(seed: u64, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut g = Gen::new(seed);
    if let Err(msg) = prop(&mut g) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counter", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failure_panics_with_seed() {
        check("fails", 10, |g| {
            if g.u8() as u32 >= 0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(123);
        let mut b = Gen::new(123);
        assert_eq!(a.bytes(0..32), b.bytes(0..32));
        assert_eq!(a.key(1..10), b.key(1..10));
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(5);
        for _ in 0..100 {
            let v = g.usize_in(3..9);
            assert!((3..9).contains(&v));
            let k = g.key(2..5);
            assert!((2..5).contains(&k.len()));
            assert!(k.iter().all(|c| c.is_ascii_lowercase()));
        }
    }
}
