//! Deterministic PRNG + samplers for workload generation and the
//! property-test harness.  xoshiro256** seeded via SplitMix64 —
//! hand-rolled because the `rand` facade is unavailable offline.

/// SplitMix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased
    /// enough for workload generation; exact rejection not needed).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte buffer.
    pub fn fill(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf(θ) sampler over `[0, n)` using the Gray–Jacobs rejection method
/// (same construction YCSB's `ZipfianGenerator` uses), so the key
/// popularity skew matches the paper's "Zipf distribution" workloads.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2: f64,
}

impl Zipf {
    /// YCSB default skew is 0.99.
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0 && theta > 0.0 && theta < 1.0);
        let zetan = Self::zeta(n, theta);
        let zeta2 = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta))
            / (1.0 - zeta2 / zetan);
        Self { n, theta, alpha, zetan, eta, zeta2 }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Exact for small n; integral approximation beyond 10^6 keeps
        // construction O(1) for huge keyspaces (error < 0.1%).
        const EXACT: u64 = 1_000_000;
        let m = n.min(EXACT);
        let mut sum = 0.0;
        for i in 1..=m {
            sum += 1.0 / (i as f64).powf(theta);
        }
        if n > m {
            // ∫ x^-θ dx from m to n
            let a = 1.0 - theta;
            sum += ((n as f64).powf(a) - (m as f64).powf(a)) / a;
        }
        sum
    }

    /// Sample a rank in `[0, n)`; rank 0 is the hottest key.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let v = (self.n as f64
            * (self.eta * u - self.eta + 1.0).powf(self.alpha))
            as u64;
        v.min(self.n - 1)
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    // Keep clippy quiet about the cached-but-derivable fields: they are
    // part of the published recurrence.
    #[doc(hidden)]
    pub fn debug_params(&self) -> (f64, f64) {
        (self.theta, self.zeta2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(1);
        for bound in [1u64, 2, 3, 10, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_covers_partial_chunks() {
        let mut r = Rng::new(3);
        for n in [0usize, 1, 7, 8, 9, 31] {
            let mut buf = vec![0u8; n];
            r.fill(&mut buf);
            if n >= 8 {
                assert!(buf.iter().any(|&b| b != 0));
            }
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(10_000, 0.99);
        let mut r = Rng::new(7);
        let mut counts = vec![0u32; 10_000];
        for _ in 0..100_000 {
            let s = z.sample(&mut r) as usize;
            assert!(s < 10_000);
            counts[s] += 1;
        }
        // Hot head: rank 0 should take a few percent of all traffic.
        assert!(counts[0] > 2_000, "rank0={}", counts[0]);
        // Tail should still be hit somewhere.
        assert!(counts[5_000..].iter().any(|&c| c > 0));
    }

    #[test]
    fn zipf_huge_keyspace_constructs_fast() {
        let z = Zipf::new(10_000_000_000, 0.99);
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            assert!(z.sample(&mut r) < 10_000_000_000);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
