//! Little-endian binary codec used for every on-disk and on-wire format
//! in the repo (WAL records, SSTable blocks, ValueLog entries, Raft
//! RPCs).  Hand-rolled because serde/prost are unavailable offline —
//! and because a storage engine wants explicit layouts anyway.

use anyhow::{bail, Result};

/// Append-only byte encoder.
#[derive(Default, Debug)]
pub struct Encoder {
    buf: Vec<u8>,
}

impl Encoder {
    pub fn new() -> Self {
        Self { buf: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        Self { buf: Vec::with_capacity(n) }
    }

    #[inline]
    pub fn u8(&mut self, v: u8) -> &mut Self {
        self.buf.push(v);
        self
    }

    #[inline]
    pub fn u16(&mut self, v: u16) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    #[inline]
    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    #[inline]
    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    /// LEB128 variable-length unsigned int (1–10 bytes).
    #[inline]
    pub fn varint(&mut self, mut v: u64) -> &mut Self {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                return self;
            }
            self.buf.push(b | 0x80);
        }
    }

    #[inline]
    pub fn bytes(&mut self, v: &[u8]) -> &mut Self {
        self.buf.extend_from_slice(v);
        self
    }

    /// varint length prefix + raw bytes.
    #[inline]
    pub fn len_bytes(&mut self, v: &[u8]) -> &mut Self {
        self.varint(v.len() as u64);
        self.bytes(v)
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    /// Overwrite 4 bytes at `pos` (for back-patched lengths/crcs).
    pub fn patch_u32(&mut self, pos: usize, v: u32) {
        self.buf[pos..pos + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// Forward-only byte decoder over a borrowed slice.
#[derive(Debug)]
pub struct Decoder<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("decode underflow: want {n}, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    #[inline]
    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    #[inline]
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    #[inline]
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    #[inline]
    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift == 63 && b > 1 {
                bail!("varint overflow");
            }
            v |= ((b & 0x7f) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
            if shift > 63 {
                bail!("varint too long");
            }
        }
    }

    #[inline]
    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    /// Counterpart of [`Encoder::len_bytes`].
    #[inline]
    pub fn len_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.varint()? as usize;
        self.take(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_fixed_width() {
        let mut e = Encoder::new();
        e.u8(0xab).u16(0xbeef).u32(0xdead_beef).u64(0x0123_4567_89ab_cdef);
        let mut d = Decoder::new(e.as_slice());
        assert_eq!(d.u8().unwrap(), 0xab);
        assert_eq!(d.u16().unwrap(), 0xbeef);
        assert_eq!(d.u32().unwrap(), 0xdead_beef);
        assert_eq!(d.u64().unwrap(), 0x0123_4567_89ab_cdef);
        assert!(d.is_empty());
    }

    #[test]
    fn roundtrip_varint_boundaries() {
        let cases = [
            0u64, 1, 127, 128, 16383, 16384,
            u32::MAX as u64, u64::MAX - 1, u64::MAX,
        ];
        let mut e = Encoder::new();
        for &c in &cases {
            e.varint(c);
        }
        let mut d = Decoder::new(e.as_slice());
        for &c in &cases {
            assert_eq!(d.varint().unwrap(), c);
        }
    }

    #[test]
    fn roundtrip_len_bytes() {
        let mut e = Encoder::new();
        e.len_bytes(b"").len_bytes(b"hello").len_bytes(&vec![7u8; 300]);
        let mut d = Decoder::new(e.as_slice());
        assert_eq!(d.len_bytes().unwrap(), b"");
        assert_eq!(d.len_bytes().unwrap(), b"hello");
        assert_eq!(d.len_bytes().unwrap(), &vec![7u8; 300][..]);
    }

    #[test]
    fn underflow_is_error_not_panic() {
        let mut d = Decoder::new(&[0x80]); // truncated varint
        assert!(d.varint().is_err());
        let mut d = Decoder::new(&[1, 2]);
        assert!(d.u32().is_err());
    }

    #[test]
    fn patch_u32_backfills() {
        let mut e = Encoder::new();
        e.u32(0);
        e.bytes(b"payload");
        e.patch_u32(0, 7);
        let mut d = Decoder::new(e.as_slice());
        assert_eq!(d.u32().unwrap(), 7);
    }

    #[test]
    fn varint_rejects_overlong() {
        // 11 continuation bytes cannot be a valid u64 varint.
        let bad = [0xffu8; 11];
        assert!(Decoder::new(&bad).varint().is_err());
    }
}
