//! Shared GC worker pool (DESIGN.md §7).
//!
//! One fixed pool per *process* — not per shard — executes the
//! key-range partitions of level merges.  Sizing follows the reactor's
//! rule (`available_parallelism` clamped to a small band) so a
//! many-shard cluster in one process cannot stampede the disk with
//! dozens of concurrent merge writers.  Each `run_parallel` call
//! windows its own submissions to the caller's `limit` (the
//! `--gc-workers` knob), so `limit = 1` degenerates to the serial
//! merge order regardless of pool size — partition *planning* is
//! deterministic and byte-identical either way; only the concurrency
//! changes.
//!
//! Workers are deprioritized (`nice(10)`) like the dedicated GC thread:
//! merge CPU must not starve the apply lane.

use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Aggregate pool counters for utilization reporting (fig10).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Microseconds workers spent executing jobs, summed across workers.
    pub busy_us: u64,
    /// Jobs completed.
    pub jobs_done: u64,
    /// Worker thread count.
    pub workers: u64,
}

pub struct GcPool {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    workers: usize,
    busy_us: AtomicU64,
    jobs_done: AtomicU64,
}

/// The process-wide pool, spawned on first use.
pub fn shared() -> &'static GcPool {
    static POOL: OnceLock<GcPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .clamp(2, 8);
        let pool = GcPool {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            workers,
            busy_us: AtomicU64::new(0),
            jobs_done: AtomicU64::new(0),
        };
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("nezha-gcpool-{i}"))
                .spawn(worker_loop)
                .expect("spawn gc pool worker");
        }
        pool
    })
}

fn worker_loop() {
    // Background work: yield the CPU to foreground request threads.
    unsafe {
        let _ = libc::nice(10);
    }
    let pool = shared();
    loop {
        let job = {
            let mut q = pool.queue.lock().expect("gc pool queue");
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = pool.available.wait(q).expect("gc pool wait");
            }
        };
        let t0 = std::time::Instant::now();
        job();
        pool.busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        pool.jobs_done.fetch_add(1, Ordering::Relaxed);
    }
}

impl GcPool {
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            busy_us: self.busy_us.load(Ordering::Relaxed),
            jobs_done: self.jobs_done.load(Ordering::Relaxed),
            workers: self.workers as u64,
        }
    }

    fn submit(&self, job: Job) {
        self.queue.lock().expect("gc pool queue").push_back(job);
        self.available.notify_one();
    }

    /// Run `tasks` on the pool with at most `limit` in flight for this
    /// call (other callers' windows are independent; the pool's worker
    /// count is the global ceiling).  Results keep task order.  The
    /// caller blocks until every task finishes — tasks themselves must
    /// never submit to the pool, or a full window could deadlock it.
    pub fn run_parallel<T, F>(&self, limit: usize, tasks: Vec<F>) -> Vec<Result<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> Result<T> + Send + 'static,
    {
        let n = tasks.len();
        let limit = limit.max(1);
        if n == 0 {
            return Vec::new();
        }
        if limit == 1 || n == 1 {
            // Serial fast path: no handoff, deterministic thread.
            return tasks.into_iter().map(|t| t()).collect();
        }
        let (tx, rx) = std::sync::mpsc::channel::<(usize, Result<T>)>();
        let mut pending = tasks.into_iter().enumerate().collect::<VecDeque<_>>();
        let mut out: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
        let mut in_flight = 0usize;
        let mut done = 0usize;
        while done < n {
            while in_flight < limit {
                let Some((i, task)) = pending.pop_front() else { break };
                let tx = tx.clone();
                self.submit(Box::new(move || {
                    let _ = tx.send((i, task()));
                }));
                in_flight += 1;
            }
            let (i, res) = rx.recv().expect("gc pool worker dropped result channel");
            out[i] = Some(res);
            in_flight -= 1;
            done += 1;
        }
        out.into_iter().map(|r| r.expect("all tasks completed")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_parallel_keeps_order_and_counts() {
        let pool = shared();
        let tasks: Vec<_> = (0..20u64)
            .map(|i| move || -> Result<u64> { Ok(i * 2) })
            .collect();
        let before = pool.stats().jobs_done;
        let got = pool.run_parallel(4, tasks);
        assert_eq!(got.len(), 20);
        for (i, r) in got.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), (i as u64) * 2);
        }
        assert!(pool.stats().jobs_done >= before + 20);
        assert!(pool.worker_count() >= 2);
    }

    #[test]
    fn serial_limit_runs_inline_and_errors_propagate_per_task() {
        let pool = shared();
        let tid = std::thread::current().id();
        let tasks: Vec<Box<dyn FnOnce() -> Result<bool> + Send>> = vec![
            Box::new(move || Ok(std::thread::current().id() == tid)),
            Box::new(|| anyhow::bail!("boom")),
        ];
        let got = pool.run_parallel(1, tasks);
        assert!(*got[0].as_ref().unwrap(), "limit=1 runs on the caller thread");
        assert!(got[1].is_err());
    }
}
