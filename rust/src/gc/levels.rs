//! Leveled Final Compacted Storage (paper §III-C/§III-D).
//!
//! The single-generation Final Compacted Storage rewrote the *entire*
//! sorted dataset every GC cycle — O(total data) write amplification
//! per cycle, exactly what WiscKey-style key-value separation was
//! meant to avoid.  This module replaces it with a **leveled run
//! stack**:
//!
//! * `levels[0]` (L0) collects one sorted run per GC cycle (the flush
//!   of a frozen epoch); deeper levels hold at most one merged run.
//! * A level is merged into the next one only when its total size
//!   exceeds its budget (`level0_bytes * fanout^depth`), so a cycle's
//!   rewrite volume is bounded by the budgets of the levels it
//!   touches, not by the total data size.
//! * Tombstones are **retained** in upper levels (they must mask older
//!   runs below) and annihilate only when a merge's output becomes the
//!   bottom of the stack.
//!
//! The [`LevelManifest`] is the single commit point: run files become
//! visible only once the manifest references them (written via
//! tmp+rename), and files outside the manifest are garbage-collected
//! on open.  Reads go through [`LeveledStorage`], which consults runs
//! newest-first — the first hit (value *or* tombstone) wins.
//!
//! One accepted trade-off: a run that *trivially moves* to the stack
//! bottom (metadata-only slide, no rewrite) keeps any tombstones it
//! carries until a future merge lands there — reads stay correct (a
//! tombstone still reports the key as absent), it only costs their
//! space until then.

use super::FinalStorage;
use crate::util::{Decoder, Encoder};
use crate::vlog::Entry as VEntry;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MANIFEST_MAGIC: u64 = 0x4E5A_4C56_4C53_0001; // "NZLVLS" v1
pub const MANIFEST_FILE: &str = "LEVELS";

/// Size budget of level `depth` (L0 = depth 0).
pub fn level_budget(level0_bytes: u64, fanout: u64, depth: usize) -> u64 {
    let mut b = level0_bytes.max(1);
    for _ in 0..depth {
        b = b.saturating_mul(fanout.max(2));
    }
    b
}

/// Wire format of a level stack (shared by [`LevelManifest`] and
/// `GcState`, which snapshots the stack — both must decode
/// identically for crash-resume replanning).
pub fn encode_levels(e: &mut Encoder, levels: &[Vec<u64>]) {
    e.varint(levels.len() as u64);
    for level in levels {
        e.varint(level.len() as u64);
        for g in level {
            e.u64(*g);
        }
    }
}

/// Inverse of [`encode_levels`].
pub fn decode_levels(d: &mut Decoder) -> Result<Vec<Vec<u64>>> {
    let nlevels = d.varint()? as usize;
    let mut levels = Vec::with_capacity(nlevels);
    for _ in 0..nlevels {
        let nruns = d.varint()? as usize;
        let mut runs = Vec::with_capacity(nruns);
        for _ in 0..nruns {
            runs.push(d.u64()?);
        }
        levels.push(runs);
    }
    Ok(levels)
}

/// Wire format of the per-run tombstone counts (`gen → count`), shared
/// by [`LevelManifest`] and `GcState`.  Appended after the level stack;
/// files written before the counts existed simply end early, which
/// [`decode_tombstone_counts`] reads as the empty ("unknown") map.
pub fn encode_tombstone_counts(e: &mut Encoder, counts: &std::collections::BTreeMap<u64, u64>) {
    e.varint(counts.len() as u64);
    for (gen, t) in counts {
        e.u64(*gen).varint(*t);
    }
}

/// Inverse of [`encode_tombstone_counts`]; an exhausted decoder yields
/// the empty map (pre-upgrade files).
pub fn decode_tombstone_counts(d: &mut Decoder) -> Result<std::collections::BTreeMap<u64, u64>> {
    let mut counts = std::collections::BTreeMap::new();
    if d.remaining() == 0 {
        return Ok(counts);
    }
    let n = d.varint()? as usize;
    for _ in 0..n {
        let gen = d.u64()?;
        let t = d.varint()?;
        counts.insert(gen, t);
    }
    Ok(counts)
}

/// CRC-framed atomic flag-file write (`crc32 | body` via tmp+rename).
/// One implementation for every GC commit-point file (`LEVELS`,
/// `GC_STATE`) so the crash-atomicity mechanics cannot drift.
///
/// The data is fsynced before the rename and the directory after it:
/// the manifest is the commit point that authorizes deleting the
/// superseded runs, so a power cut must never journal the rename
/// while the bytes (or the directory entry) are still in flight.
pub(crate) fn save_framed(dir: &Path, name: &str, body: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut framed = Encoder::with_capacity(body.len() + 4);
    framed.u32(crc32fast::hash(body)).bytes(body);
    let tmp = dir.join(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(framed.as_slice())?;
        crate::fault::disk::check(&tmp, crate::fault::disk::DiskOp::Sync)?;
        f.sync_data()?;
    }
    std::fs::rename(tmp, dir.join(name))?;
    std::fs::File::open(dir)?.sync_data()?;
    Ok(())
}

/// Inverse of [`save_framed`]: `Ok(None)` when the file is absent,
/// an error on CRC mismatch.
pub(crate) fn load_framed(dir: &Path, name: &str) -> Result<Option<Vec<u8>>> {
    let buf = match std::fs::read(dir.join(name)) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut d = Decoder::new(&buf);
    let crc = d.u32()?;
    let body = d.bytes(d.remaining())?;
    anyhow::ensure!(crc32fast::hash(body) == crc, "{name} crc mismatch");
    Ok(Some(body.to_vec()))
}

/// Durable description of the level stack: `levels[d]` lists the run
/// generations at depth `d`, newest first.  `next_gen` is the next
/// unused generation number (monotonic across the directory's life).
/// `run_tombstones` counts the tombstone frames per run so a trivial
/// move to the stack bottom knows whether a rewrite (annihilation) is
/// worth it — tombstone-free runs slide as pure metadata.  A run
/// missing from the map (pre-upgrade manifests) reads as "unknown"
/// and is conservatively rewritten once.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelManifest {
    pub levels: Vec<Vec<u64>>,
    pub next_gen: u64,
    pub run_tombstones: std::collections::BTreeMap<u64, u64>,
}

impl Default for LevelManifest {
    fn default() -> Self {
        Self { levels: Vec::new(), next_gen: 1, run_tombstones: Default::default() }
    }
}

impl LevelManifest {
    /// Every referenced generation, top level first.
    pub fn all_gens(&self) -> Vec<u64> {
        self.levels.iter().flatten().copied().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut e = Encoder::new();
        e.u64(MANIFEST_MAGIC).u64(self.next_gen);
        encode_levels(&mut e, &self.levels);
        encode_tombstone_counts(&mut e, &self.run_tombstones);
        save_framed(dir, MANIFEST_FILE, &e.into_vec())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let Some(body) = load_framed(dir, MANIFEST_FILE)? else {
            return Ok(None);
        };
        let mut d = Decoder::new(&body);
        if d.u64()? != MANIFEST_MAGIC {
            bail!("level manifest bad magic");
        }
        let next_gen = d.u64()?;
        let levels = decode_levels(&mut d)?;
        let run_tombstones = decode_tombstone_counts(&mut d)?;
        Ok(Some(Self { levels, next_gen, run_tombstones }))
    }
}

/// The open run stack: one [`FinalStorage`] per run, addressed
/// newest-first within each level, shallowest level first.
#[derive(Default)]
pub struct LeveledStorage {
    pub levels: Vec<Vec<FinalStorage>>,
}

impl LeveledStorage {
    pub fn open(dir: &Path, gens: &[Vec<u64>]) -> Result<Self> {
        Self::open_reusing(dir, gens, &mut Self::default())
    }

    /// Open the stack described by `gens`, adopting already-open run
    /// handles from `prev` where the generation matches (so swapping
    /// manifests does not re-read unchanged indexes).
    ///
    /// Exception-safe: every missing run is opened *before* `prev` is
    /// consumed, so on error the caller's stack is left untouched —
    /// the engine must keep serving reads from the committed stack if
    /// a manifest swap fails mid-way.
    pub fn open_reusing(dir: &Path, gens: &[Vec<u64>], prev: &mut Self) -> Result<Self> {
        let held: std::collections::HashSet<u64> =
            prev.runs_newest_first().map(|r| r.gen).collect();
        let mut fresh: std::collections::HashMap<u64, FinalStorage> =
            std::collections::HashMap::new();
        for &g in gens.iter().flatten() {
            if !held.contains(&g) && !fresh.contains_key(&g) {
                let run = FinalStorage::open(dir, g)
                    .with_context(|| format!("leveled storage run {g}"))?;
                fresh.insert(g, run);
            }
        }
        // Infallible from here on.
        let mut pool: std::collections::HashMap<u64, FinalStorage> = std::mem::take(prev)
            .levels
            .into_iter()
            .flatten()
            .map(|r| (r.gen, r))
            .collect();
        pool.extend(fresh);
        let levels = gens
            .iter()
            .map(|level| {
                level
                    .iter()
                    .map(|g| pool.remove(g).expect("run pre-opened or adopted"))
                    .collect()
            })
            .collect();
        Ok(Self { levels })
    }

    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    pub fn run_count(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    pub fn level_count(&self) -> usize {
        self.levels.iter().filter(|l| !l.is_empty()).count()
    }

    /// Runs in read-precedence order: shallowest level first, newest
    /// run first within a level.
    pub fn runs_newest_first(&self) -> impl Iterator<Item = &FinalStorage> {
        self.levels.iter().flatten()
    }

    /// Runs in merge-precedence order for scans: oldest first, so a
    /// BTreeMap insert sweep lets newer runs overwrite older keys.
    pub fn runs_oldest_first(&self) -> impl Iterator<Item = &FinalStorage> {
        self.levels.iter().rev().flat_map(|l| l.iter().rev())
    }

    /// Point lookup, newest-first.  The first run containing the key
    /// wins — a retained tombstone (`value == None`) masks every older
    /// run, exactly like the LSM chain above it.
    pub fn get(&self, key: &[u8]) -> Result<Option<VEntry>> {
        for run in self.runs_newest_first() {
            if let Some(e) = run.get(key)? {
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    /// Batched point lookup: each run is consulted once with the still
    /// unresolved subset of keys (offset-ordered verification inside
    /// [`FinalStorage::multi_get`]); a hit — value or tombstone —
    /// settles the key so deeper runs never see it.
    pub fn multi_get(&self, keys: &[&[u8]]) -> Result<Vec<Option<VEntry>>> {
        let mut out: Vec<Option<VEntry>> = vec![None; keys.len()];
        let mut pending: Vec<usize> = (0..keys.len()).collect();
        for run in self.runs_newest_first() {
            if pending.is_empty() {
                break;
            }
            let sub: Vec<&[u8]> = pending.iter().map(|&i| keys[i]).collect();
            let got = run.multi_get(&sub)?;
            let mut still = Vec::with_capacity(pending.len());
            for (&slot, e) in pending.iter().zip(got) {
                match e {
                    Some(e) => out[slot] = Some(e),
                    None => still.push(slot),
                }
            }
            pending = still;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nezha-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(LevelManifest::load(&dir).unwrap(), None);
        let m = LevelManifest {
            levels: vec![vec![5, 3], vec![], vec![1]],
            next_gen: 6,
            run_tombstones: [(5, 2), (3, 0), (1, 7)].into_iter().collect(),
        };
        m.save(&dir).unwrap();
        assert_eq!(LevelManifest::load(&dir).unwrap(), Some(m.clone()));
        assert_eq!(m.all_gens(), vec![5, 3, 1]);
        assert!(!m.is_empty());
        assert!(LevelManifest::default().is_empty());
    }

    /// A manifest written before per-run tombstone counts existed (no
    /// trailing count map) still loads, with the counts read as
    /// "unknown" (empty map).
    #[test]
    fn manifest_without_tombstone_counts_still_loads() {
        let dir =
            std::env::temp_dir().join(format!("nezha-manifest-pretomb-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut e = Encoder::new();
        e.u64(MANIFEST_MAGIC).u64(4);
        let stack = vec![vec![3], vec![1]];
        encode_levels(&mut e, &stack);
        save_framed(&dir, MANIFEST_FILE, &e.into_vec()).unwrap();
        let m = LevelManifest::load(&dir).unwrap().expect("legacy manifest loads");
        assert_eq!(m.levels, stack);
        assert_eq!(m.next_gen, 4);
        assert!(m.run_tombstones.is_empty());
    }

    #[test]
    fn budgets_grow_geometrically() {
        assert_eq!(level_budget(1 << 20, 10, 0), 1 << 20);
        assert_eq!(level_budget(1 << 20, 10, 1), 10 << 20);
        assert_eq!(level_budget(1 << 20, 10, 2), 100 << 20);
        // Saturates instead of overflowing.
        assert_eq!(level_budget(u64::MAX, 10, 3), u64::MAX);
        // Degenerate fanouts are clamped so budgets still grow.
        assert!(level_budget(1024, 0, 2) > level_budget(1024, 0, 1));
    }
}
