//! Leveled Final Compacted Storage (paper §III-C/§III-D).
//!
//! The single-generation Final Compacted Storage rewrote the *entire*
//! sorted dataset every GC cycle — O(total data) write amplification
//! per cycle, exactly what WiscKey-style key-value separation was
//! meant to avoid.  This module replaces it with a **leveled run
//! stack**:
//!
//! * `levels[0]` (L0) collects one sorted run per GC cycle (the flush
//!   of a frozen epoch); deeper levels hold at most one merged run.
//! * A level is merged into the next one only when its total size
//!   exceeds its budget (`level0_bytes * fanout^depth`), so a cycle's
//!   rewrite volume is bounded by the budgets of the levels it
//!   touches, not by the total data size.
//! * Tombstones are **retained** in upper levels (they must mask older
//!   runs below) and annihilate only when a merge's output becomes the
//!   bottom of the stack.
//!
//! The [`LevelManifest`] is the single commit point: run files become
//! visible only once the manifest references them (written via
//! tmp+rename), and files outside the manifest are garbage-collected
//! on open.  Reads go through [`LeveledStorage`], which consults runs
//! newest-first — the first hit (value *or* tombstone) wins.
//!
//! One accepted trade-off: a run that *trivially moves* to the stack
//! bottom (metadata-only slide, no rewrite) keeps any tombstones it
//! carries until a future merge lands there — reads stay correct (a
//! tombstone still reports the key as absent), it only costs their
//! space until then.

use super::FinalStorage;
use crate::util::{Decoder, Encoder};
use crate::vlog::Entry as VEntry;
use anyhow::{bail, Context, Result};
use std::path::Path;

const MANIFEST_MAGIC: u64 = 0x4E5A_4C56_4C53_0001; // "NZLVLS" v1
pub const MANIFEST_FILE: &str = "LEVELS";

/// Size budget of level `depth` (L0 = depth 0).
pub fn level_budget(level0_bytes: u64, fanout: u64, depth: usize) -> u64 {
    let mut b = level0_bytes.max(1);
    for _ in 0..depth {
        b = b.saturating_mul(fanout.max(2));
    }
    b
}

/// Wire format of a level stack (shared by [`LevelManifest`] and
/// `GcState`, which snapshots the stack — both must decode
/// identically for crash-resume replanning).
pub fn encode_levels(e: &mut Encoder, levels: &[Vec<u64>]) {
    e.varint(levels.len() as u64);
    for level in levels {
        e.varint(level.len() as u64);
        for g in level {
            e.u64(*g);
        }
    }
}

/// Inverse of [`encode_levels`].
pub fn decode_levels(d: &mut Decoder) -> Result<Vec<Vec<u64>>> {
    let nlevels = d.varint()? as usize;
    let mut levels = Vec::with_capacity(nlevels);
    for _ in 0..nlevels {
        let nruns = d.varint()? as usize;
        let mut runs = Vec::with_capacity(nruns);
        for _ in 0..nruns {
            runs.push(d.u64()?);
        }
        levels.push(runs);
    }
    Ok(levels)
}

/// Wire format of the per-run tombstone counts (`gen → count`), shared
/// by [`LevelManifest`] and `GcState`.  Appended after the level stack;
/// files written before the counts existed simply end early, which
/// [`decode_tombstone_counts`] reads as the empty ("unknown") map.
pub fn encode_tombstone_counts(e: &mut Encoder, counts: &std::collections::BTreeMap<u64, u64>) {
    e.varint(counts.len() as u64);
    for (gen, t) in counts {
        e.u64(*gen).varint(*t);
    }
}

/// Inverse of [`encode_tombstone_counts`]; an exhausted decoder yields
/// the empty map (pre-upgrade files).
pub fn decode_tombstone_counts(d: &mut Decoder) -> Result<std::collections::BTreeMap<u64, u64>> {
    let mut counts = std::collections::BTreeMap::new();
    if d.remaining() == 0 {
        return Ok(counts);
    }
    let n = d.varint()? as usize;
    for _ in 0..n {
        let gen = d.u64()?;
        let t = d.varint()?;
        counts.insert(gen, t);
    }
    Ok(counts)
}

/// A *partitioned run*: one logical sorted run physically split into
/// key-disjoint sub-runs by a parallel merge.  `gens` lists the
/// sub-run generations in ascending key order; `bounds[i]` is the
/// first key of `gens[i + 1]`'s range (so sub-run `i` covers keys
/// `< bounds[i]`, the last covers everything from `bounds` up).
///
/// Group membership is keyed purely by generation numbers, so a
/// trivial move (the gens slide to a deeper level) needs no partition
/// metadata update.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PartitionGroup {
    pub gens: Vec<u64>,
    pub bounds: Vec<Vec<u8>>,
}

impl PartitionGroup {
    /// Index of the sub-run whose key range contains `key`.
    pub fn part_for(bounds: &[Vec<u8>], key: &[u8]) -> usize {
        bounds.partition_point(|b| b.as_slice() <= key)
    }
}

/// Wire format of the partition groups, shared by [`LevelManifest`]
/// and `GcState`.  Appended after the tombstone counts; files written
/// before partitioned runs existed end early, which
/// [`decode_partitions`] reads as "no groups" (every run a singleton).
pub fn encode_partitions(e: &mut Encoder, groups: &[PartitionGroup]) {
    e.varint(groups.len() as u64);
    for g in groups {
        e.varint(g.gens.len() as u64);
        for gen in &g.gens {
            e.u64(*gen);
        }
        for b in &g.bounds {
            e.len_bytes(b);
        }
    }
}

/// Inverse of [`encode_partitions`]; an exhausted decoder yields the
/// empty list (pre-partition files).
pub fn decode_partitions(d: &mut Decoder) -> Result<Vec<PartitionGroup>> {
    if d.remaining() == 0 {
        return Ok(Vec::new());
    }
    let ngroups = d.varint()? as usize;
    let mut groups = Vec::with_capacity(ngroups);
    for _ in 0..ngroups {
        let ngens = d.varint()? as usize;
        anyhow::ensure!(ngens >= 1, "partition group without sub-runs");
        let mut gens = Vec::with_capacity(ngens);
        for _ in 0..ngens {
            gens.push(d.u64()?);
        }
        let mut bounds = Vec::with_capacity(ngens - 1);
        for _ in 0..ngens - 1 {
            bounds.push(d.len_bytes()?.to_vec());
        }
        groups.push(PartitionGroup { gens, bounds });
    }
    Ok(groups)
}

/// CRC-framed atomic flag-file write (`crc32 | body` via tmp+rename).
/// One implementation for every GC commit-point file (`LEVELS`,
/// `GC_STATE`) so the crash-atomicity mechanics cannot drift.
///
/// The data is fsynced before the rename and the directory after it:
/// the manifest is the commit point that authorizes deleting the
/// superseded runs, so a power cut must never journal the rename
/// while the bytes (or the directory entry) are still in flight.
pub(crate) fn save_framed(dir: &Path, name: &str, body: &[u8]) -> Result<()> {
    use std::io::Write;
    let mut framed = Encoder::with_capacity(body.len() + 4);
    framed.u32(crc32fast::hash(body)).bytes(body);
    let tmp = dir.join(format!("{name}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(framed.as_slice())?;
        crate::fault::disk::check(&tmp, crate::fault::disk::DiskOp::Sync)?;
        f.sync_data()?;
    }
    std::fs::rename(tmp, dir.join(name))?;
    std::fs::File::open(dir)?.sync_data()?;
    Ok(())
}

/// Inverse of [`save_framed`]: `Ok(None)` when the file is absent,
/// an error on CRC mismatch.
pub(crate) fn load_framed(dir: &Path, name: &str) -> Result<Option<Vec<u8>>> {
    let buf = match std::fs::read(dir.join(name)) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(e.into()),
    };
    let mut d = Decoder::new(&buf);
    let crc = d.u32()?;
    let body = d.bytes(d.remaining())?;
    anyhow::ensure!(crc32fast::hash(body) == crc, "{name} crc mismatch");
    Ok(Some(body.to_vec()))
}

/// Durable description of the level stack: `levels[d]` lists the run
/// generations at depth `d`, newest first.  `next_gen` is the next
/// unused generation number (monotonic across the directory's life).
/// `run_tombstones` counts the tombstone frames per run so a trivial
/// move to the stack bottom knows whether a rewrite (annihilation) is
/// worth it — tombstone-free runs slide as pure metadata.  A run
/// missing from the map (pre-upgrade manifests) reads as "unknown"
/// and is conservatively rewritten once.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LevelManifest {
    pub levels: Vec<Vec<u64>>,
    pub next_gen: u64,
    pub run_tombstones: std::collections::BTreeMap<u64, u64>,
    /// Partition groups for levels whose entries are partitioned runs;
    /// a generation in no group is a plain single-run entry.
    pub partitions: Vec<PartitionGroup>,
}

impl Default for LevelManifest {
    fn default() -> Self {
        Self {
            levels: Vec::new(),
            next_gen: 1,
            run_tombstones: Default::default(),
            partitions: Vec::new(),
        }
    }
}

impl LevelManifest {
    /// Every referenced generation, top level first.
    pub fn all_gens(&self) -> Vec<u64> {
        self.levels.iter().flatten().copied().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    /// Drop partition groups that no longer have all their members in
    /// the level stack (their merge output superseded them).
    pub fn retain_live_partitions(&mut self) {
        let live: std::collections::HashSet<u64> = self.all_gens().into_iter().collect();
        self.partitions.retain(|p| p.gens.iter().all(|g| live.contains(g)));
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut e = Encoder::new();
        e.u64(MANIFEST_MAGIC).u64(self.next_gen);
        encode_levels(&mut e, &self.levels);
        encode_tombstone_counts(&mut e, &self.run_tombstones);
        encode_partitions(&mut e, &self.partitions);
        save_framed(dir, MANIFEST_FILE, &e.into_vec())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let Some(body) = load_framed(dir, MANIFEST_FILE)? else {
            return Ok(None);
        };
        let mut d = Decoder::new(&body);
        if d.u64()? != MANIFEST_MAGIC {
            bail!("level manifest bad magic");
        }
        let next_gen = d.u64()?;
        let levels = decode_levels(&mut d)?;
        let run_tombstones = decode_tombstone_counts(&mut d)?;
        let partitions = decode_partitions(&mut d)?;
        Ok(Some(Self { levels, next_gen, run_tombstones, partitions }))
    }
}

/// One logical run of a level: either a single sealed run, or a
/// partitioned run's key-disjoint sub-runs in ascending key order.
/// Point reads binary-search `bounds` to touch exactly one sub-run.
pub struct LogicalRun {
    pub parts: Vec<FinalStorage>,
    pub bounds: Vec<Vec<u8>>,
}

impl LogicalRun {
    fn single(run: FinalStorage) -> Self {
        Self { parts: vec![run], bounds: Vec::new() }
    }

    pub fn gens(&self) -> impl Iterator<Item = u64> + '_ {
        self.parts.iter().map(|r| r.gen)
    }

    /// The sub-run whose key range contains `key`.
    pub fn part_for(&self, key: &[u8]) -> &FinalStorage {
        &self.parts[PartitionGroup::part_for(&self.bounds, key)]
    }

    pub fn get(&self, key: &[u8]) -> Result<Option<VEntry>> {
        self.part_for(key).get(key)
    }

    /// Batched lookup: route each key to its sub-run by bound search,
    /// one [`FinalStorage::multi_get`] batch per touched sub-run.
    pub fn multi_get(&self, keys: &[&[u8]]) -> Result<Vec<Option<VEntry>>> {
        if self.parts.len() == 1 {
            return self.parts[0].multi_get(keys);
        }
        let mut out: Vec<Option<VEntry>> = vec![None; keys.len()];
        let mut by_part: Vec<Vec<usize>> = vec![Vec::new(); self.parts.len()];
        for (i, k) in keys.iter().enumerate() {
            by_part[PartitionGroup::part_for(&self.bounds, k)].push(i);
        }
        for (p, slots) in by_part.iter().enumerate() {
            if slots.is_empty() {
                continue;
            }
            let sub: Vec<&[u8]> = slots.iter().map(|&i| keys[i]).collect();
            for (&slot, e) in slots.iter().zip(self.parts[p].multi_get(&sub)?) {
                out[slot] = e;
            }
        }
        Ok(out)
    }

    /// Range scan: start at the sub-run containing `start`, then walk
    /// the following sub-runs — they are key-disjoint and ordered, so
    /// concatenation stays sorted.  An empty `end` means unbounded.
    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<VEntry>> {
        if self.parts.len() == 1 {
            return self.parts[0].scan(start, end, limit);
        }
        let first = PartitionGroup::part_for(&self.bounds, start);
        let mut out: Vec<VEntry> = Vec::new();
        for (p, run) in self.parts.iter().enumerate().skip(first) {
            if p > first && !end.is_empty() && self.bounds[p - 1].as_slice() >= end {
                break; // sub-run starts at or past the scan end
            }
            if out.len() >= limit {
                break;
            }
            out.extend(run.scan(start, end, limit - out.len())?);
        }
        Ok(out)
    }
}

/// The open run stack: one [`LogicalRun`] per run (single or
/// partitioned), addressed newest-first within each level, shallowest
/// level first.
#[derive(Default)]
pub struct LeveledStorage {
    pub levels: Vec<Vec<LogicalRun>>,
}

/// Assemble one level's flat gen list into logical runs: a maximal
/// contiguous slice matching a [`PartitionGroup`]'s gens becomes one
/// partitioned run; everything else is a singleton.  (The committer
/// always writes a group's gens contiguously and in key order, so a
/// non-contiguous group — a corrupt manifest — degrades to singletons,
/// which still reads correctly, just without bound pruning.)
fn group_level(
    level: &[u64],
    partitions: &[PartitionGroup],
    take: &mut impl FnMut(u64) -> FinalStorage,
) -> Vec<LogicalRun> {
    let group_of: std::collections::HashMap<u64, usize> = partitions
        .iter()
        .enumerate()
        .flat_map(|(gi, p)| p.gens.iter().map(move |&g| (g, gi)))
        .collect();
    let mut runs = Vec::new();
    let mut i = 0;
    while i < level.len() {
        let g = level[i];
        if let Some(&gi) = group_of.get(&g) {
            let grp = &partitions[gi];
            if level[i..].starts_with(&grp.gens) {
                let parts = grp.gens.iter().map(|&g| take(g)).collect();
                runs.push(LogicalRun { parts, bounds: grp.bounds.clone() });
                i += grp.gens.len();
                continue;
            }
        }
        runs.push(LogicalRun::single(take(g)));
        i += 1;
    }
    runs
}

impl LeveledStorage {
    pub fn open(dir: &Path, gens: &[Vec<u64>]) -> Result<Self> {
        Self::open_partitioned(dir, gens, &[])
    }

    pub fn open_partitioned(
        dir: &Path,
        gens: &[Vec<u64>],
        partitions: &[PartitionGroup],
    ) -> Result<Self> {
        Self::open_reusing(dir, gens, partitions, &mut Self::default())
    }

    /// Open the stack described by `gens`, adopting already-open run
    /// handles from `prev` where the generation matches (so swapping
    /// manifests does not re-read unchanged indexes).
    ///
    /// Exception-safe: every missing run is opened *before* `prev` is
    /// consumed, so on error the caller's stack is left untouched —
    /// the engine must keep serving reads from the committed stack if
    /// a manifest swap fails mid-way.
    pub fn open_reusing(
        dir: &Path,
        gens: &[Vec<u64>],
        partitions: &[PartitionGroup],
        prev: &mut Self,
    ) -> Result<Self> {
        let held: std::collections::HashSet<u64> = prev.subruns().map(|r| r.gen).collect();
        let mut fresh: std::collections::HashMap<u64, FinalStorage> =
            std::collections::HashMap::new();
        for &g in gens.iter().flatten() {
            if !held.contains(&g) && !fresh.contains_key(&g) {
                let run = FinalStorage::open(dir, g)
                    .with_context(|| format!("leveled storage run {g}"))?;
                fresh.insert(g, run);
            }
        }
        // Infallible from here on.
        let mut pool: std::collections::HashMap<u64, FinalStorage> = std::mem::take(prev)
            .levels
            .into_iter()
            .flatten()
            .flat_map(|r| r.parts)
            .map(|r| (r.gen, r))
            .collect();
        pool.extend(fresh);
        let mut take = |g: u64| pool.remove(&g).expect("run pre-opened or adopted");
        let levels = gens
            .iter()
            .map(|level| group_level(level, partitions, &mut take))
            .collect();
        Ok(Self { levels })
    }

    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    /// Total physical sub-runs (a partitioned run counts each part).
    pub fn run_count(&self) -> usize {
        self.levels.iter().flatten().map(|r| r.parts.len()).sum()
    }

    pub fn level_count(&self) -> usize {
        self.levels.iter().filter(|l| !l.is_empty()).count()
    }

    /// Logical runs in read-precedence order: shallowest level first,
    /// newest run first within a level.
    pub fn runs_newest_first(&self) -> impl Iterator<Item = &LogicalRun> {
        self.levels.iter().flatten()
    }

    /// Logical runs in merge-precedence order for scans: oldest first,
    /// so a BTreeMap insert sweep lets newer runs overwrite older keys.
    pub fn runs_oldest_first(&self) -> impl Iterator<Item = &LogicalRun> {
        self.levels.iter().rev().flat_map(|l| l.iter().rev())
    }

    /// Every physical sub-run, in no particular precedence order
    /// (bookkeeping walks: open-handle adoption, byte counting).
    pub fn subruns(&self) -> impl Iterator<Item = &FinalStorage> {
        self.levels.iter().flatten().flat_map(|r| r.parts.iter())
    }

    /// Point lookup, newest-first.  The first logical run containing
    /// the key wins — a retained tombstone (`value == None`) masks
    /// every older run, exactly like the LSM chain above it.  Within a
    /// partitioned run only the sub-run owning the key is consulted.
    pub fn get(&self, key: &[u8]) -> Result<Option<VEntry>> {
        for run in self.runs_newest_first() {
            if let Some(e) = run.get(key)? {
                return Ok(Some(e));
            }
        }
        Ok(None)
    }

    /// Batched point lookup: each logical run is consulted once with
    /// the still unresolved subset of keys (offset-ordered
    /// verification inside [`FinalStorage::multi_get`]); a hit — value
    /// or tombstone — settles the key so deeper runs never see it.
    pub fn multi_get(&self, keys: &[&[u8]]) -> Result<Vec<Option<VEntry>>> {
        let mut out: Vec<Option<VEntry>> = vec![None; keys.len()];
        let mut pending: Vec<usize> = (0..keys.len()).collect();
        for run in self.runs_newest_first() {
            if pending.is_empty() {
                break;
            }
            let sub: Vec<&[u8]> = pending.iter().map(|&i| keys[i]).collect();
            let got = run.multi_get(&sub)?;
            let mut still = Vec::with_capacity(pending.len());
            for (&slot, e) in pending.iter().zip(got) {
                match e {
                    Some(e) => out[slot] = Some(e),
                    None => still.push(slot),
                }
            }
            pending = still;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nezha-manifest-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(LevelManifest::load(&dir).unwrap(), None);
        let m = LevelManifest {
            levels: vec![vec![5, 3], vec![], vec![1]],
            next_gen: 6,
            run_tombstones: [(5, 2), (3, 0), (1, 7)].into_iter().collect(),
            partitions: Vec::new(),
        };
        m.save(&dir).unwrap();
        assert_eq!(LevelManifest::load(&dir).unwrap(), Some(m.clone()));
        assert_eq!(m.all_gens(), vec![5, 3, 1]);
        assert!(!m.is_empty());
        assert!(LevelManifest::default().is_empty());
    }

    /// A manifest carrying partitioned runs round-trips, and dropping
    /// a group member from the stack drops the whole group.
    #[test]
    fn partitioned_manifest_roundtrip() {
        let dir = std::env::temp_dir().join(format!("nezha-manifest-part-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let grp = PartitionGroup {
            gens: vec![7, 8, 9],
            bounds: vec![b"g".to_vec(), b"p".to_vec()],
        };
        let mut m = LevelManifest {
            levels: vec![vec![10], vec![7, 8, 9]],
            next_gen: 11,
            run_tombstones: [(7, 1)].into_iter().collect(),
            partitions: vec![grp.clone()],
        };
        m.save(&dir).unwrap();
        assert_eq!(LevelManifest::load(&dir).unwrap(), Some(m.clone()));
        // Superseding gen 8 invalidates the whole group.
        m.levels = vec![vec![10], vec![7, 9]];
        m.retain_live_partitions();
        assert!(m.partitions.is_empty());
        assert_eq!(
            PartitionGroup::part_for(&grp.bounds, b"a"),
            0,
            "keys below the first bound route to part 0"
        );
        assert_eq!(PartitionGroup::part_for(&grp.bounds, b"g"), 1);
        assert_eq!(PartitionGroup::part_for(&grp.bounds, b"z"), 2);
    }

    /// A manifest written before partitioned runs existed (levels +
    /// tombstone counts, no trailing partition section) still loads,
    /// with every run read as a singleton.
    #[test]
    fn pre_partition_manifest_still_loads() {
        let dir =
            std::env::temp_dir().join(format!("nezha-manifest-prepart-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut e = Encoder::new();
        e.u64(MANIFEST_MAGIC).u64(5);
        let stack = vec![vec![4], vec![2]];
        encode_levels(&mut e, &stack);
        encode_tombstone_counts(&mut e, &[(4, 3)].into_iter().collect());
        save_framed(&dir, MANIFEST_FILE, &e.into_vec()).unwrap();
        let m = LevelManifest::load(&dir).unwrap().expect("pre-partition manifest loads");
        assert_eq!(m.levels, stack);
        assert_eq!(m.next_gen, 5);
        assert!(m.partitions.is_empty());
    }

    /// A manifest written before per-run tombstone counts existed (no
    /// trailing count map) still loads, with the counts read as
    /// "unknown" (empty map).
    #[test]
    fn manifest_without_tombstone_counts_still_loads() {
        let dir =
            std::env::temp_dir().join(format!("nezha-manifest-pretomb-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut e = Encoder::new();
        e.u64(MANIFEST_MAGIC).u64(4);
        let stack = vec![vec![3], vec![1]];
        encode_levels(&mut e, &stack);
        save_framed(&dir, MANIFEST_FILE, &e.into_vec()).unwrap();
        let m = LevelManifest::load(&dir).unwrap().expect("legacy manifest loads");
        assert_eq!(m.levels, stack);
        assert_eq!(m.next_gen, 4);
        assert!(m.run_tombstones.is_empty());
    }

    #[test]
    fn budgets_grow_geometrically() {
        assert_eq!(level_budget(1 << 20, 10, 0), 1 << 20);
        assert_eq!(level_budget(1 << 20, 10, 1), 10 << 20);
        assert_eq!(level_budget(1 << 20, 10, 2), 100 << 20);
        // Saturates instead of overflowing.
        assert_eq!(level_budget(u64::MAX, 10, 3), u64::MAX);
        // Degenerate fanouts are clamped so budgets still grow.
        assert!(level_budget(1024, 0, 2) > level_budget(1024, 0, 1));
    }
}
