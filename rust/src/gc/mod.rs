//! Raft-aware garbage collection framework (paper §III-C).
//!
//! A GC cycle takes the frozen Active Storage (one raft ValueLog epoch
//! + its key→VRef LSM) plus the previous Final Compacted Storage, and
//! produces a new Final Compacted Storage: a key-ordered
//! [`SortedVLog`] + [`HashIndex`].  The sorted log carries
//! `(last_term, last_index)` so it doubles as the Raft snapshot.
//!
//! Lifecycle (paper's four phases):
//! 1. **GC initialization** — the replica rotates the raft log epoch
//!    (freezing the Active ValueLog), the engine freezes its LSM and
//!    opens fresh ones (the New Storage), and persists a [`GcState`]
//!    flag file.
//! 2. **Data compaction** — [`run_gc`] (on a background thread) merges
//!    the frozen epoch's live entries with the previous sorted log.
//! 3. **Cleanup** — the engine swaps in the new [`FinalStorage`],
//!    deletes the old generation + frozen LSM, and the replica marks
//!    the Raft snapshot and drops the old epoch files.
//! 4. **Steady state** — the New Storage has become the Active
//!    Storage; the cycle can repeat.
//!
//! Crash recovery: if [`GcState`] says a cycle was running, the engine
//! resumes from the last key in the partial sorted file
//! ([`SortedVLogWriter::resume`]) — §III-E.

use crate::util::{Decoder, Encoder};
use crate::vlog::{Entry as VEntry, HashIndex, SortedVLog, SortedVLogWriter, VLogReader};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// The request-processing phase (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GcPhase {
    /// Only the Active Storage exists.
    Pre,
    /// New Storage + (frozen) Active Storage.
    During,
    /// New Storage + Final Compacted Storage.
    Post,
}

/// GC trigger policy (paper: "multidimensional triggers, including
/// storage space thresholds, scheduled timing mechanisms, and request
/// load levels").
#[derive(Clone, Debug)]
pub struct GcConfig {
    /// Active ValueLog size trigger (paper's 40 GB, scaled).
    pub threshold_bytes: u64,
    /// Minimum logical time between cycles (scheduled trigger floor).
    pub min_interval_ms: u64,
    /// Skip triggering while apply-queue pressure is above this many
    /// entries (load-level trigger: don't GC under peak load).
    pub max_load_entries: u64,
    /// Build the hash index through the AOT XLA planner when available.
    pub use_xla_planner: bool,
}

impl Default for GcConfig {
    fn default() -> Self {
        Self {
            threshold_bytes: 64 << 20,
            min_interval_ms: 0,
            max_load_entries: u64::MAX,
            use_xla_planner: true,
        }
    }
}

/// Persistent GC progress flag ("the recovery process first checks the
/// atomic GC state flag" — §III-E).  Written atomically via tmp+rename.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GcState {
    pub running: bool,
    pub frozen_epoch: u32,
    pub out_gen: u64,
    pub last_index: u64,
    pub last_term: u64,
}

impl GcState {
    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut e = Encoder::with_capacity(40);
        e.u8(self.running as u8)
            .u32(self.frozen_epoch)
            .u64(self.out_gen)
            .u64(self.last_index)
            .u64(self.last_term);
        let body = e.into_vec();
        let mut framed = Encoder::with_capacity(body.len() + 4);
        framed.u32(crc32fast::hash(&body)).bytes(&body);
        let tmp = dir.join("GC_STATE.tmp");
        std::fs::write(&tmp, framed.as_slice())?;
        std::fs::rename(tmp, dir.join("GC_STATE"))?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let buf = match std::fs::read(dir.join("GC_STATE")) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        let mut d = Decoder::new(&buf);
        let crc = d.u32()?;
        let body = d.bytes(d.remaining())?;
        anyhow::ensure!(crc32fast::hash(body) == crc, "gc state crc mismatch");
        let mut d = Decoder::new(body);
        Ok(Some(Self {
            running: d.u8()? != 0,
            frozen_epoch: d.u32()?,
            out_gen: d.u64()?,
            last_index: d.u64()?,
            last_term: d.u64()?,
        }))
    }

    pub fn clear(dir: &Path) -> Result<()> {
        match std::fs::remove_file(dir.join("GC_STATE")) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

/// The Final Compacted Storage module: sorted ValueLog + hash index.
pub struct FinalStorage {
    pub log: SortedVLog,
    pub index: HashIndex,
    pub gen: u64,
}

pub fn sorted_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("sorted-{gen:06}.vlog"))
}

pub fn index_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("sorted-{gen:06}.idx"))
}

impl FinalStorage {
    pub fn open(dir: &Path, gen: u64) -> Result<Self> {
        let log = SortedVLog::open(&sorted_path(dir, gen))?;
        let index = HashIndex::load(&index_path(dir, gen))
            .context("final storage index load")?;
        Ok(Self { log, index, gen })
    }

    /// Point lookup via the hash index (one random read on hit —
    /// paper §IV-C2).
    pub fn get(&self, key: &[u8]) -> Result<Option<VEntry>> {
        self.index.lookup(key, &self.log)
    }

    /// Batched point lookup: gather every key's candidate offsets from
    /// the hash index first, then verify them against the sorted log in
    /// a single offset-ordered pass (forward-only I/O instead of one
    /// random read per key).  Results align with `keys`.
    pub fn multi_get(&self, keys: &[&[u8]]) -> Result<Vec<Option<VEntry>>> {
        let mut cands: Vec<(usize, u64)> = Vec::with_capacity(keys.len());
        for (i, k) in keys.iter().enumerate() {
            for off in self.index.candidates(k) {
                cands.push((i, off));
            }
        }
        cands.sort_unstable_by_key(|&(_, off)| off);
        let mut out: Vec<Option<VEntry>> = vec![None; keys.len()];
        for (i, off) in cands {
            if out[i].is_some() {
                continue; // a key appears at most once in a sorted log
            }
            let e = self.log.read(off).context("final storage candidate read")?;
            if e.key == keys[i] {
                out[i] = Some(e);
            }
        }
        Ok(out)
    }

    /// Range scan: one random read for the start position, then
    /// sequential (paper §IV-C3).
    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<VEntry>> {
        let from = self.index.scan_start(start);
        self.log.scan_from(from, start, end, limit)
    }

    /// Discover the newest complete generation in `dir`.
    pub fn latest_gen(dir: &Path) -> Result<Option<u64>> {
        let mut best = None;
        let rd = match std::fs::read_dir(dir) {
            Ok(r) => r,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e.into()),
        };
        for entry in rd {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("sorted-").and_then(|s| s.strip_suffix(".idx")) {
                if let Ok(g) = num.parse::<u64>() {
                    best = Some(best.map_or(g, |b: u64| b.max(g)));
                }
            }
        }
        Ok(best)
    }

    pub fn remove_gen(dir: &Path, gen: u64) {
        let _ = std::fs::remove_file(sorted_path(dir, gen));
        let _ = std::fs::remove_file(index_path(dir, gen));
    }
}

/// Hash/bucket provider for index construction — either the pure-Rust
/// hash or the AOT XLA planner ([`crate::runtime::IndexPlanner`]).
pub trait IndexBackend: Send + Sync {
    /// For each key return `(h1, bucket)` where `bucket = h1 %
    /// n_buckets`.
    fn plan(&self, keys: &[&[u8]], n_buckets: u32) -> Result<(Vec<u32>, Vec<u32>)>;
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (always available; bit-identical to the kernel).
pub struct RustBackend;

impl IndexBackend for RustBackend {
    fn plan(&self, keys: &[&[u8]], n_buckets: u32) -> Result<(Vec<u32>, Vec<u32>)> {
        let mut h = Vec::with_capacity(keys.len());
        let mut b = Vec::with_capacity(keys.len());
        let nb = n_buckets.max(1);
        for k in keys {
            let (h1, _) = crate::vlog::hash::hash_pair(k);
            h.push(h1);
            b.push(h1 % nb);
        }
        Ok((h, b))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// What a finished cycle hands back to the replica.
#[derive(Debug)]
pub struct GcOutput {
    pub gen: u64,
    pub entries: u64,
    pub bytes_written: u64,
    pub last_index: u64,
    pub last_term: u64,
    pub wall_ms: u64,
    pub index_backend: &'static str,
}

/// Inputs for one compaction cycle (runs on a background thread; only
/// touches frozen files).
pub struct GcInputs {
    /// Frozen Active-Storage ValueLog (raft epoch file).
    pub frozen_vlog_path: PathBuf,
    /// Previous Final Compacted Storage generation, if any.
    pub prev_gen: Option<u64>,
    /// Output directory (holds sorted-*.vlog/idx).
    pub dir: PathBuf,
    pub out_gen: u64,
    pub last_index: u64,
    pub last_term: u64,
    /// Resume a partially-written output (crash recovery).
    pub resume: bool,
    pub backend: Arc<dyn IndexBackend>,
}

/// Run one GC compaction cycle to completion.
pub fn run_gc(inp: &GcInputs) -> Result<GcOutput> {
    let t0 = std::time::Instant::now();

    // (1) Latest-per-key view of the frozen epoch.  File order is
    // index order, so later entries overwrite earlier ones.
    let mut fresh: BTreeMap<Vec<u8>, VEntry> = BTreeMap::new();
    let reader = VLogReader::open(&inp.frozen_vlog_path)?;
    for item in reader.iter()? {
        let (_, e) = item?;
        if e.index > inp.last_index {
            break; // beyond the snapshot point (uncommitted tail)
        }
        if e.key.is_empty() && e.value.is_none() {
            continue; // raft noop
        }
        fresh.insert(e.key.clone(), e);
    }

    // (2+3) Merge with the previous sorted generation, streaming into
    // the new sorted log. Tombstones annihilate and are dropped.
    let out_path = sorted_path(&inp.dir, inp.out_gen);
    let mut w = if inp.resume && out_path.exists() {
        SortedVLogWriter::resume(&out_path)?
    } else {
        SortedVLogWriter::create(&out_path, inp.last_term, inp.last_index)?
    };
    let resume_after: Option<Vec<u8>> = w.last_key().map(|k| k.to_vec());

    let prev = match inp.prev_gen {
        Some(g) => Some(SortedVLog::open(&sorted_path(&inp.dir, g))?),
        None => None,
    };
    let mut prev_iter = prev.as_ref().map(|p| p.iter().peekable());
    let mut fresh_iter = fresh.into_iter().peekable();

    let skip = |key: &[u8]| resume_after.as_deref().map_or(false, |ra| key <= ra);
    loop {
        // Classic two-way sorted merge; fresh wins ties.
        let take_fresh = match (fresh_iter.peek(), prev_iter.as_mut().and_then(|i| i.peek())) {
            (None, None) => break,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some((fk, _)), Some(Ok((_, pe)))) => fk.as_slice() <= pe.key.as_slice(),
            (_, Some(Err(_))) => true, // surface the error below
        };
        if take_fresh {
            let (k, e) = fresh_iter.next().unwrap();
            // Skip an equal key on the prev side (superseded).
            if let Some(pi) = prev_iter.as_mut() {
                if matches!(pi.peek(), Some(Ok((_, pe))) if pe.key == k) {
                    pi.next();
                }
            }
            if e.value.is_some() && !skip(&k) {
                w.add(&e)?;
            }
            // Tombstone: drop (annihilates the prev entry too).
        } else {
            let item = prev_iter.as_mut().unwrap().next().unwrap();
            let (_, e) = item?;
            if e.value.is_some() && !skip(&e.key) {
                w.add(&e)?;
            }
        }
    }

    let entries = w.entry_count() as u64;
    let (bytes, key_offsets) = w.finish()?;

    // (4) Hash index via the configured backend.
    let cap = HashIndex::capacity_for(key_offsets.len()) as u32;
    let keys: Vec<&[u8]> = key_offsets.iter().map(|(k, _)| k.as_slice()).collect();
    let (hashes, buckets) = inp.backend.plan(&keys, cap)?;
    let index = HashIndex::build_from_planner(&key_offsets, &hashes, &buckets)?;
    index.save(&index_path(&inp.dir, inp.out_gen))?;

    Ok(GcOutput {
        gen: inp.out_gen,
        entries,
        bytes_written: bytes,
        last_index: inp.last_index,
        last_term: inp.last_term,
        wall_ms: t0.elapsed().as_millis() as u64,
        index_backend: inp.backend.name(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vlog::VLog;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-gc-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_epoch(dir: &Path, entries: &[VEntry]) -> PathBuf {
        let p = dir.join("raft-000000.vlog");
        let mut v = VLog::open(&p).unwrap();
        for e in entries {
            v.append(e).unwrap();
        }
        v.sync().unwrap();
        p
    }

    fn inputs(dir: &Path, vlog: PathBuf, prev: Option<u64>, gen: u64, last_index: u64) -> GcInputs {
        GcInputs {
            frozen_vlog_path: vlog,
            prev_gen: prev,
            dir: dir.to_path_buf(),
            out_gen: gen,
            last_index,
            last_term: 1,
            resume: false,
            backend: Arc::new(RustBackend),
        }
    }

    #[test]
    fn first_cycle_sorts_and_dedups() {
        let dir = tmpdir("first");
        let vlog = write_epoch(
            &dir,
            &[
                VEntry::put(1, 1, "b", "1"),
                VEntry::put(1, 2, "a", "1"),
                VEntry::put(1, 3, "b", "2"), // overwrites
                VEntry::put(1, 4, "c", "1"),
                VEntry::delete(1, 5, "c"), // tombstone annihilates
            ],
        );
        let out = run_gc(&inputs(&dir, vlog, None, 1, 5)).unwrap();
        assert_eq!(out.entries, 2);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        assert_eq!(fs.log.last_index, 5);
        assert_eq!(fs.get(b"b").unwrap().unwrap().value, Some(b"2".to_vec()));
        assert_eq!(fs.get(b"a").unwrap().unwrap().value, Some(b"1".to_vec()));
        assert!(fs.get(b"c").unwrap().is_none());
        // Scan is ordered.
        let scan = fs.scan(b"", b"zzz", 10).unwrap();
        assert_eq!(scan.len(), 2);
        assert_eq!(scan[0].key, b"a".to_vec());
    }

    #[test]
    fn second_cycle_merges_previous_generation() {
        let dir = tmpdir("second");
        let v1 = write_epoch(
            &dir,
            &[VEntry::put(1, 1, "a", "old"), VEntry::put(1, 2, "b", "old"), VEntry::put(1, 3, "d", "old")],
        );
        run_gc(&inputs(&dir, v1, None, 1, 3)).unwrap();
        // Second epoch: update b, delete d, add c.
        let p2 = dir.join("raft-000001.vlog");
        let mut v = VLog::open(&p2).unwrap();
        v.append(&VEntry::put(2, 4, "b", "new")).unwrap();
        v.append(&VEntry::delete(2, 5, "d")).unwrap();
        v.append(&VEntry::put(2, 6, "c", "new")).unwrap();
        v.sync().unwrap();
        let out = run_gc(&inputs(&dir, p2, Some(1), 2, 6)).unwrap();
        assert_eq!(out.entries, 3); // a, b, c
        let fs = FinalStorage::open(&dir, 2).unwrap();
        assert_eq!(fs.get(b"a").unwrap().unwrap().value, Some(b"old".to_vec()));
        assert_eq!(fs.get(b"b").unwrap().unwrap().value, Some(b"new".to_vec()));
        assert_eq!(fs.get(b"c").unwrap().unwrap().value, Some(b"new".to_vec()));
        assert!(fs.get(b"d").unwrap().is_none());
        assert_eq!(fs.log.last_index, 6);
    }

    #[test]
    fn uncommitted_tail_excluded() {
        let dir = tmpdir("tail");
        let vlog = write_epoch(
            &dir,
            &[VEntry::put(1, 1, "a", "1"), VEntry::put(1, 2, "b", "1"), VEntry::put(1, 3, "x", "uncommitted")],
        );
        // last_index = 2: entry 3 must not appear.
        run_gc(&inputs(&dir, vlog, None, 1, 2)).unwrap();
        let fs = FinalStorage::open(&dir, 1).unwrap();
        assert!(fs.get(b"x").unwrap().is_none());
        assert!(fs.get(b"a").unwrap().is_some());
    }

    #[test]
    fn resume_continues_from_interrupt_point() {
        let dir = tmpdir("resume");
        let entries: Vec<VEntry> = (0..100u64)
            .map(|i| VEntry::put(1, i + 1, format!("key{i:04}"), format!("v{i}")))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        // Simulate an interrupted first run: write a partial sorted
        // file by hand (first 30 keys).
        {
            let mut w = SortedVLogWriter::create(&sorted_path(&dir, 1), 1, 100).unwrap();
            for e in entries.iter().take(30) {
                w.add(e).unwrap();
            }
            w.finish().unwrap();
        }
        let mut inp = inputs(&dir, vlog, None, 1, 100);
        inp.resume = true;
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.entries, 100);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        for i in (0..100u64).step_by(9) {
            let k = format!("key{i:04}");
            assert_eq!(
                fs.get(k.as_bytes()).unwrap().unwrap().value,
                Some(format!("v{i}").into_bytes()),
                "{k}"
            );
        }
        // No duplicates: scan count matches.
        assert_eq!(fs.scan(b"", b"z", 1000).unwrap().len(), 100);
    }

    #[test]
    fn final_storage_multi_get_matches_get() {
        let dir = tmpdir("mget");
        let entries: Vec<VEntry> = (0..400u64)
            .map(|i| VEntry::put(1, i + 1, format!("key{i:04}"), format!("v{i}")))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        run_gc(&inputs(&dir, vlog, None, 1, 400)).unwrap();
        let fs = FinalStorage::open(&dir, 1).unwrap();
        // Unsorted request order, present and absent keys mixed.
        let keys: Vec<Vec<u8>> = (0..500u64)
            .rev()
            .step_by(7)
            .map(|i| format!("key{i:04}").into_bytes())
            .collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
        let batched = fs.multi_get(&refs).unwrap();
        assert_eq!(batched.len(), keys.len());
        for (k, b) in keys.iter().zip(&batched) {
            assert_eq!(*b, fs.get(k).unwrap(), "{}", String::from_utf8_lossy(k));
        }
    }

    #[test]
    fn gc_state_flag_roundtrip() {
        let dir = tmpdir("state");
        assert_eq!(GcState::load(&dir).unwrap(), None);
        let st = GcState { running: true, frozen_epoch: 3, out_gen: 2, last_index: 55, last_term: 4 };
        st.save(&dir).unwrap();
        assert_eq!(GcState::load(&dir).unwrap(), Some(st));
        GcState::clear(&dir).unwrap();
        assert_eq!(GcState::load(&dir).unwrap(), None);
    }

    #[test]
    fn latest_gen_discovery() {
        let dir = tmpdir("gens");
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), None);
        let v = write_epoch(&dir, &[VEntry::put(1, 1, "a", "1")]);
        run_gc(&inputs(&dir, v.clone(), None, 1, 1)).unwrap();
        run_gc(&inputs(&dir, v, Some(1), 2, 1)).unwrap();
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), Some(2));
        FinalStorage::remove_gen(&dir, 2);
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), Some(1));
    }

    #[test]
    fn large_cycle_roundtrips() {
        let dir = tmpdir("large");
        let entries: Vec<VEntry> = (0..5000u64)
            .map(|i| VEntry::put(1, i + 1, format!("user{:08}", i * 7 % 5000), vec![(i % 251) as u8; 64]))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        let out = run_gc(&inputs(&dir, vlog, None, 1, 5000)).unwrap();
        assert!(out.entries > 0);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        let all = fs.scan(b"", b"z", 100_000).unwrap();
        assert_eq!(all.len() as u64, out.entries);
        for w in all.windows(2) {
            assert!(w[0].key < w[1].key);
        }
    }
}
