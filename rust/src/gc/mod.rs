//! Raft-aware garbage collection framework (paper §III-C/§III-D;
//! DESIGN.md §3 documents the leveling discipline and its crash
//! contract).
//!
//! A GC cycle takes the frozen Active Storage (the raft ValueLog
//! epochs frozen since the last snapshot point, plus the frozen
//! key→VRef LSM) and **flushes** its live entries into a new L0 sorted
//! run of the leveled Final Compacted Storage ([`levels`]).  No other
//! data is rewritten unless a level exceeds its size budget, in which
//! case that level is merged into the next one — so a cycle's write
//! volume is bounded by the budgets of the levels it touches instead
//! of growing with the total dataset (the leveled-LSM discipline
//! applied to the sorted ValueLog).
//!
//! Lifecycle (paper's four phases):
//! 1. **GC initialization** — the replica rotates the raft log epoch
//!    (freezing the Active ValueLog), the engine freezes its LSM and
//!    opens fresh ones (the New Storage), and persists a [`GcState`]
//!    flag file recording the input epochs and the committed stack.
//! 2. **Data compaction** — [`run_gc`] (on a background thread)
//!    flushes the frozen epochs' live entries into a new L0 run, then
//!    performs any budget-triggered level merges.
//! 3. **Cleanup** — the engine commits the new [`levels::LevelManifest`]
//!    (the single atomic commit point), deletes superseded run files +
//!    the frozen LSM, and the replica marks the Raft snapshot and
//!    drops fully-covered epoch files.
//! 4. **Steady state** — the New Storage has become the Active
//!    Storage; the cycle can repeat.
//!
//! Crash recovery (§III-E): if [`GcState`] says a cycle was running,
//! the engine re-runs the cycle with `resume = true`.  Both the flush
//! and every level merge are deterministic given the committed stack,
//! so each output run resumes from the last key of its partial file
//! ([`SortedVLogWriter::resume`]) and completed steps re-verify as
//! no-ops.  Tombstones are retained in upper levels and annihilate
//! only when a merge's output becomes the bottom of the stack.
//!
//! Sealed runs are also the unit of follower catch-up: a streamed
//! snapshot ships them as files (DESIGN.md §8), so the engine pins
//! shipped generations and GC defers — never skips — deleting a
//! superseded run while a transfer holds it.

pub mod levels;
pub mod pool;

use crate::util::{Decoder, Encoder};
use crate::vlog::{Entry as VEntry, HashIndex, SortedVLog, SortedVLogWriter, VLogReader};
use anyhow::{Context, Result};
use levels::{
    decode_levels, decode_partitions, encode_levels, encode_partitions, level_budget,
    load_framed, save_framed, PartitionGroup,
};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Ceiling on key-range partitions per level merge (matches the GC
/// pool's worker ceiling — more partitions than workers only adds seal
/// overhead).
pub const MAX_PARTS: usize = 8;

/// The request-processing phase (Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GcPhase {
    /// Only the Active Storage exists.
    Pre,
    /// New Storage + (frozen) Active Storage.
    During,
    /// New Storage + Final Compacted Storage.
    Post,
}

/// GC trigger policy (paper: "multidimensional triggers, including
/// storage space thresholds, scheduled timing mechanisms, and request
/// load levels").
#[derive(Clone, Debug)]
pub struct GcConfig {
    /// Active ValueLog size trigger (paper's 40 GB, scaled).
    pub threshold_bytes: u64,
    /// Minimum logical time between cycles (scheduled trigger floor).
    pub min_interval_ms: u64,
    /// Skip triggering while apply-queue pressure is above this many
    /// entries (load-level trigger: don't GC under peak load).  The
    /// cycle's snapshot point is `last_applied`, so a bounded backlog
    /// never blocks GC — only genuine overload defers it.
    pub max_load_entries: u64,
    /// Build the hash index through the AOT XLA planner when available.
    pub use_xla_planner: bool,
}

impl Default for GcConfig {
    fn default() -> Self {
        Self {
            threshold_bytes: 64 << 20,
            min_interval_ms: 0,
            max_load_entries: 4096,
            use_xla_planner: true,
        }
    }
}

/// One frozen raft epoch feeding a GC cycle: its id plus the first
/// byte offset that may still hold uncompacted entries.  The previous
/// cycle records the offset (see [`GcOutput::skip_offsets`]) so a
/// backlog-tail epoch is re-read from its tail instead of from byte 0;
/// `skip_offset = 0` (unknown) is always safe — the flush filters by
/// index either way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrozenEpoch {
    pub epoch: u32,
    pub skip_offset: u64,
}

impl FrozenEpoch {
    /// An epoch with no recorded skip point (read from the start).
    pub fn new(epoch: u32) -> Self {
        Self { epoch, skip_offset: 0 }
    }
}

/// Persistent GC progress flag ("the recovery process first checks the
/// atomic GC state flag" — §III-E).  Written atomically via tmp+rename.
///
/// Besides the frozen-epoch range and output generation it records the
/// committed level stack at cycle start — and the stack runs' tombstone
/// counts, which gate the trivial-move-vs-rewrite decision — so a
/// resumed cycle replans the exact same flush + merge sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GcState {
    pub running: bool,
    /// Oldest retained frozen epoch feeding this cycle.
    pub min_epoch: u32,
    /// Newest frozen epoch feeding this cycle.
    pub frozen_epoch: u32,
    /// Generation of the flush (L0) output run.
    pub out_gen: u64,
    /// Entries with `index <= min_index` are already in the stack.
    pub min_index: u64,
    pub last_index: u64,
    pub last_term: u64,
    /// Committed level stack (run gens, newest-first per level) when
    /// the cycle began.
    pub stack: Vec<Vec<u64>>,
    /// Tombstone frames per stack run (`gen → count`) at cycle start.
    /// Runs absent from the map (pre-upgrade flag files) read as
    /// "unknown" and are conservatively treated as tombstone-carrying.
    pub run_tombstones: std::collections::BTreeMap<u64, u64>,
    /// Partition groups of the committed stack at cycle start, so a
    /// resumed cycle replans with the same logical-run structure.
    pub partitions: Vec<PartitionGroup>,
}

impl GcState {
    /// Serialized length of the pre-leveled (single-generation) format:
    /// `running u8 + frozen_epoch u32 + out_gen/last_index/last_term
    /// u64`.  The leveled format is ≥ 42 bytes, so the length
    /// disambiguates and old flag files keep decoding after an upgrade.
    const LEGACY_BODY_LEN: usize = 29;

    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut e = Encoder::with_capacity(64);
        e.u8(self.running as u8)
            .u32(self.min_epoch)
            .u32(self.frozen_epoch)
            .u64(self.out_gen)
            .u64(self.min_index)
            .u64(self.last_index)
            .u64(self.last_term);
        encode_levels(&mut e, &self.stack);
        levels::encode_tombstone_counts(&mut e, &self.run_tombstones);
        encode_partitions(&mut e, &self.partitions);
        save_framed(dir, "GC_STATE", &e.into_vec())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let Some(body) = load_framed(dir, "GC_STATE")? else {
            return Ok(None);
        };
        let body = body.as_slice();
        let mut d = Decoder::new(body);
        if body.len() == Self::LEGACY_BODY_LEN {
            // Pre-leveled flag file: single frozen epoch, no recorded
            // stack (the engine substitutes the adopted legacy stack
            // and restarts the cycle's output — the old full-merge
            // partial output is not resumable under leveled flushes).
            let running = d.u8()? != 0;
            let frozen_epoch = d.u32()?;
            return Ok(Some(Self {
                running,
                min_epoch: frozen_epoch,
                frozen_epoch,
                out_gen: d.u64()?,
                min_index: 0,
                last_index: d.u64()?,
                last_term: d.u64()?,
                stack: Vec::new(),
                run_tombstones: Default::default(),
                partitions: Vec::new(),
            }));
        }
        let running = d.u8()? != 0;
        let min_epoch = d.u32()?;
        let frozen_epoch = d.u32()?;
        let out_gen = d.u64()?;
        let min_index = d.u64()?;
        let last_index = d.u64()?;
        let last_term = d.u64()?;
        let stack = decode_levels(&mut d)?;
        // Flag files written before tombstone counts (or partition
        // groups) end early; the empty collections read as "unknown" /
        // "all singletons" downstream.
        let run_tombstones = levels::decode_tombstone_counts(&mut d)?;
        let partitions = decode_partitions(&mut d)?;
        Ok(Some(Self {
            running,
            min_epoch,
            frozen_epoch,
            out_gen,
            min_index,
            last_index,
            last_term,
            stack,
            run_tombstones,
            partitions,
        }))
    }

    pub fn clear(dir: &Path) -> Result<()> {
        match std::fs::remove_file(dir.join("GC_STATE")) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

/// One sorted run of the Final Compacted Storage: sorted ValueLog +
/// hash index.  Runs are stacked into levels by
/// [`levels::LeveledStorage`].
pub struct FinalStorage {
    pub log: SortedVLog,
    pub index: HashIndex,
    pub gen: u64,
}

pub fn sorted_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("sorted-{gen:06}.vlog"))
}

pub fn index_path(dir: &Path, gen: u64) -> PathBuf {
    dir.join(format!("sorted-{gen:06}.idx"))
}

impl FinalStorage {
    pub fn open(dir: &Path, gen: u64) -> Result<Self> {
        let log = SortedVLog::open(&sorted_path(dir, gen))?;
        let index = HashIndex::load(&index_path(dir, gen))
            .context("final storage index load")?;
        Ok(Self { log, index, gen })
    }

    /// Point lookup via the hash index (one random read on hit —
    /// paper §IV-C2).  A hit may be a retained tombstone
    /// (`value == None`); callers must let it mask older runs.
    pub fn get(&self, key: &[u8]) -> Result<Option<VEntry>> {
        self.index.lookup(key, &self.log)
    }

    /// Batched point lookup: gather every key's candidate offsets from
    /// the hash index first, then verify them against the sorted log in
    /// a single offset-ordered pass (forward-only I/O instead of one
    /// random read per key).  Results align with `keys`.
    pub fn multi_get(&self, keys: &[&[u8]]) -> Result<Vec<Option<VEntry>>> {
        let mut cands: Vec<(usize, u64)> = Vec::with_capacity(keys.len());
        for (i, k) in keys.iter().enumerate() {
            for off in self.index.candidates(k) {
                cands.push((i, off));
            }
        }
        cands.sort_unstable_by_key(|&(_, off)| off);
        let mut out: Vec<Option<VEntry>> = vec![None; keys.len()];
        for (i, off) in cands {
            if out[i].is_some() {
                continue; // a key appears at most once in a sorted run
            }
            let e = self.log.read(off).context("final storage candidate read")?;
            if e.key == keys[i] {
                out[i] = Some(e);
            }
        }
        Ok(out)
    }

    /// Range scan: one random read for the start position, then
    /// sequential (paper §IV-C3).  An empty `end` means unbounded.
    pub fn scan(&self, start: &[u8], end: &[u8], limit: usize) -> Result<Vec<VEntry>> {
        let from = self.index.scan_start(start);
        self.log.scan_from(from, start, end, limit)
    }

    fn scan_gens(dir: &Path, suffix: &str, out: &mut Vec<u64>) -> Result<()> {
        let rd = match std::fs::read_dir(dir) {
            Ok(r) => r,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(e.into()),
        };
        for entry in rd {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("sorted-").and_then(|s| s.strip_suffix(suffix)) {
                if let Ok(g) = num.parse::<u64>() {
                    out.push(g);
                }
            }
        }
        Ok(())
    }

    /// List every *sealed* generation (index file present) in `dir`.
    pub fn list_gens(dir: &Path) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        Self::scan_gens(dir, ".idx", &mut out)?;
        out.sort_unstable();
        Ok(out)
    }

    /// List every generation with *any* on-disk file — sealed runs and
    /// partial (unsealed) outputs alike.  Cleanup paths must use this:
    /// a partial `.vlog` without its `.idx` is invisible to
    /// [`Self::list_gens`] but, left behind across generation reuse, a
    /// later cycle's resume could adopt it.
    pub fn list_all_gens(dir: &Path) -> Result<Vec<u64>> {
        let mut out = Vec::new();
        Self::scan_gens(dir, ".idx", &mut out)?;
        Self::scan_gens(dir, ".vlog", &mut out)?;
        out.sort_unstable();
        out.dedup();
        Ok(out)
    }

    /// Discover the newest complete generation in `dir` (legacy
    /// single-generation layouts, adopted as a bottom level on open).
    pub fn latest_gen(dir: &Path) -> Result<Option<u64>> {
        Ok(Self::list_gens(dir)?.last().copied())
    }

    pub fn remove_gen(dir: &Path, gen: u64) {
        let _ = std::fs::remove_file(sorted_path(dir, gen));
        let _ = std::fs::remove_file(index_path(dir, gen));
    }
}

/// Hash/bucket provider for index construction — either the pure-Rust
/// hash or the AOT XLA planner ([`crate::runtime::IndexPlanner`]).
pub trait IndexBackend: Send + Sync {
    /// For each key return `(h1, bucket)` where `bucket = h1 %
    /// n_buckets`.
    fn plan(&self, keys: &[&[u8]], n_buckets: u32) -> Result<(Vec<u32>, Vec<u32>)>;
    fn name(&self) -> &'static str;
}

/// Pure-Rust backend (always available; bit-identical to the kernel).
pub struct RustBackend;

impl IndexBackend for RustBackend {
    fn plan(&self, keys: &[&[u8]], n_buckets: u32) -> Result<(Vec<u32>, Vec<u32>)> {
        let mut h = Vec::with_capacity(keys.len());
        let mut b = Vec::with_capacity(keys.len());
        let nb = n_buckets.max(1);
        for k in keys {
            let (h1, _) = crate::vlog::hash::hash_pair(k);
            h.push(h1);
            b.push(h1 % nb);
        }
        Ok((h, b))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// What a finished cycle hands back to the replica.
#[derive(Clone, Debug)]
pub struct GcOutput {
    /// Generation of the flushed L0 run.
    pub gen: u64,
    /// Entries in the flushed L0 run (tombstones included unless the
    /// run became the bottom of the stack).
    pub entries: u64,
    /// Bytes written by the epoch flush alone.
    pub flush_bytes: u64,
    /// Bytes written by budget-triggered level merges.
    pub merge_bytes: u64,
    /// Total bytes this cycle wrote (`flush_bytes + merge_bytes`).
    pub bytes_written: u64,
    /// Number of level merges the cycle performed.
    pub merges: u64,
    /// Resulting level stack (run gens, newest-first per level).
    pub levels: Vec<Vec<u64>>,
    /// Every generation the cycle wrote (flush + merge outputs),
    /// whether or not it survived into `levels`.
    pub written_gens: Vec<u64>,
    /// Tombstone frames in every run the cycle wrote, `(gen, count)`
    /// (manifest bookkeeping for the trivial-move annihilation rule).
    pub run_tombstones: Vec<(u64, u64)>,
    /// Per input epoch: the first byte offset holding entries above
    /// this cycle's snapshot point — the next cycle's flush seeks
    /// straight to it instead of re-reading the compacted prefix.
    pub skip_offsets: Vec<(u32, u64)>,
    pub last_index: u64,
    pub last_term: u64,
    pub wall_ms: u64,
    pub index_backend: &'static str,
    /// Partition groups of the resulting stack (parallel merges leave
    /// their outputs as key-disjoint sub-runs of one logical run).
    pub partitions: Vec<PartitionGroup>,
    /// Largest partition fan-out any merge in this output used (1 =
    /// every merge was a single-run rewrite, 0 = no merges).
    pub parts: u64,
    /// True when this output reports a decoupled background merge job
    /// rather than a flush cycle (no epochs to reclaim — the stack
    /// just got cheaper).
    pub is_merge_job: bool,
}

/// One frozen ValueLog file feeding a cycle's flush: the epoch id, its
/// on-disk path and the byte offset the flush may seek to (everything
/// below it is already compacted; 0 = read from the start).
pub struct EpochSource {
    pub epoch: u32,
    pub path: PathBuf,
    pub skip_offset: u64,
}

/// Inputs for one compaction cycle (runs on a background thread; only
/// touches frozen files — the committed stack is read-only input and
/// new runs become visible only when the engine commits the manifest).
pub struct GcInputs {
    /// Frozen Active-Storage ValueLogs (raft epoch files), oldest
    /// first.  Multiple files appear when earlier cycles froze with an
    /// apply backlog: the uncompacted tails ride along here.
    pub frozen: Vec<EpochSource>,
    /// Output directory (holds sorted-*.vlog/idx + manifest).
    pub dir: PathBuf,
    /// Generation for the flush output; merge outputs take successive
    /// generations after it.
    pub out_gen: u64,
    /// Committed level stack at cycle start.
    pub stack: Vec<Vec<u64>>,
    /// Tombstone frames per stack run.  A run missing from the map is
    /// treated as tombstone-carrying (pre-upgrade manifests), so a
    /// trivial move to the stack bottom rewrites it once.
    pub run_tombstones: std::collections::BTreeMap<u64, u64>,
    /// Entries with `index <= min_index` are already in the stack.
    pub min_index: u64,
    pub last_index: u64,
    pub last_term: u64,
    /// L0 size budget; level `d` gets `level0_bytes * fanout^d`.
    pub level0_bytes: u64,
    pub fanout: u64,
    /// Partition groups of the committed stack at cycle start.
    pub partitions: Vec<PartitionGroup>,
    /// Target source bytes per merge partition: a level merge splits
    /// into `ceil(total / partition_bytes)` key ranges (≤ [`MAX_PARTS`]).
    /// `u64::MAX` disables partitioning.  Derived from immutable sealed
    /// file sizes, so the plan — and the resulting byte-identical stack
    /// — is independent of worker count and stable across resume.
    pub partition_bytes: u64,
    /// Max partitions merged concurrently (1 = serial; concurrency
    /// never changes the plan, only the wall clock).
    pub workers: usize,
    /// Resume partially-written outputs (crash recovery).
    pub resume: bool,
    pub backend: Arc<dyn IndexBackend>,
}

/// Open a run writer, resuming the partial file when recovering.
///
/// A resumable file must carry THIS cycle's `(last_term, last_index)`
/// in its header: generation numbers can be reused after
/// `install_snapshot` discards a failed cycle, and adopting a stale
/// file from a different cycle would resurrect pre-snapshot data.  A
/// header mismatch (or a torn header) starts the run from scratch.
fn open_writer(
    path: &Path,
    resume: bool,
    last_term: u64,
    last_index: u64,
) -> Result<SortedVLogWriter> {
    if resume && path.exists() {
        if let Ok(existing) = SortedVLog::open(path) {
            if existing.last_term == last_term && existing.last_index == last_index {
                return SortedVLogWriter::resume(path);
            }
        }
    }
    SortedVLogWriter::create(path, last_term, last_index)
}

/// Finish a run: build + save its hash index through the configured
/// backend, return `(bytes, entries, tombstones)`.  Shared by the GC
/// cycle and `install_snapshot` so every sorted run — GC-produced or
/// snapshot-materialized — is sealed through the same path.
pub(crate) fn seal_run(
    dir: &Path,
    gen: u64,
    w: SortedVLogWriter,
    backend: &Arc<dyn IndexBackend>,
) -> Result<(u64, u64, u64)> {
    let entries = w.entry_count() as u64;
    let tombstones = w.tombstone_count() as u64;
    let (bytes, key_offsets) = w.finish()?;
    let cap = HashIndex::capacity_for(key_offsets.len()) as u32;
    let keys: Vec<&[u8]> = key_offsets.iter().map(|(k, _)| k.as_slice()).collect();
    let (hashes, buckets) = backend.plan(&keys, cap)?;
    let index = HashIndex::build_from_planner(&key_offsets, &hashes, &buckets)?;
    index.save(&index_path(dir, gen))?;
    Ok((bytes, entries, tombstones))
}

/// Rebuild the hash index of an already-sealed run file from scratch by
/// scanning its entries.  Used by streamed snapshot install (DESIGN.md
/// §8): the sender ships only `.vlog` run files — indexes are
/// receiver-local artifacts, cheaper to rebuild than to ship.  Returns
/// `(entries, tombstones)` for the receiver's manifest bookkeeping.
pub(crate) fn rebuild_index_for_gen(
    dir: &Path,
    gen: u64,
    backend: &Arc<dyn IndexBackend>,
) -> Result<(u64, u64)> {
    let log = SortedVLog::open(&sorted_path(dir, gen))?;
    let mut key_offsets: Vec<(Vec<u8>, u64)> = Vec::new();
    let mut tombstones = 0u64;
    for item in log.iter() {
        let (off, e) = item?;
        if e.value.is_none() {
            tombstones += 1;
        }
        key_offsets.push((e.key, off));
    }
    let entries = key_offsets.len() as u64;
    let cap = HashIndex::capacity_for(key_offsets.len()) as u32;
    let keys: Vec<&[u8]> = key_offsets.iter().map(|(k, _)| k.as_slice()).collect();
    let (hashes, buckets) = backend.plan(&keys, cap)?;
    let index = HashIndex::build_from_planner(&key_offsets, &hashes, &buckets)?;
    index.save(&index_path(dir, gen))?;
    Ok((entries, tombstones))
}

/// Flush the frozen epochs' live entries (`min_index < index <=
/// last_index`, latest-per-key) into the run `out_gen`.  Tombstones are
/// dropped only when `annihilate` (the run becomes the stack bottom).
///
/// Concurrency note: since the trigger may freeze an epoch that still
/// holds an *uncommitted* tail, Raft conflict resolution can truncate
/// and rewrite that tail while this thread reads the file.  That is
/// safe: `last_index` is a committed (applied) index, rewritten frames
/// always carry indexes above it and are filtered out, and a torn
/// frame fails its CRC — the cycle errors and retries after restart
/// instead of absorbing bad data.
fn flush_epochs(
    inp: &GcInputs,
    annihilate: bool,
) -> Result<(u64, u64, u64, Vec<(u32, u64)>)> {
    let mut fresh: BTreeMap<Vec<u8>, VEntry> = BTreeMap::new();
    let mut skips: Vec<(u32, u64)> = Vec::with_capacity(inp.frozen.len());
    for src in &inp.frozen {
        let reader = VLogReader::open(&src.path)?;
        // Offsets and indexes grow together within an epoch file, so
        // the already-compacted prefix (`index <= min_index`) is a
        // byte prefix: seek straight past it, and record where THIS
        // cycle's coverage ends for the next cycle to seek to.
        let mut next_skip: Option<u64> = None;
        for item in reader.iter_from(src.skip_offset)? {
            let (off, e) = item?;
            if e.index > inp.last_index {
                if next_skip.is_none() {
                    next_skip = Some(off);
                }
                continue; // beyond the snapshot point (next cycle's work)
            }
            if e.index <= inp.min_index {
                continue; // already compacted
            }
            if e.key.is_empty() && e.value.is_none() {
                continue; // raft noop
            }
            // Highest index wins (robust even if conflict truncation
            // left overlapping index ranges across epoch files).
            let superseded = matches!(fresh.get(&e.key), Some(old) if old.index > e.index);
            if !superseded {
                fresh.insert(e.key.clone(), e);
            }
        }
        // Fully covered epoch: the next cycle may skip the whole file
        // (it will normally be dropped by the snapshot anyway).
        let skip = match next_skip {
            Some(off) => off,
            None => std::fs::metadata(&src.path)?.len(),
        };
        skips.push((src.epoch, skip));
    }
    let out_path = sorted_path(&inp.dir, inp.out_gen);
    let mut w = open_writer(&out_path, inp.resume, inp.last_term, inp.last_index)?;
    let resume_after: Option<Vec<u8>> = w.last_key().map(|k| k.to_vec());
    for (k, e) in fresh {
        if annihilate && e.value.is_none() {
            continue;
        }
        if resume_after.as_deref().is_some_and(|ra| k.as_slice() <= ra) {
            continue;
        }
        w.add(&e)?;
    }
    let (bytes, entries, tombs) = seal_run(&inp.dir, inp.out_gen, w, &inp.backend)?;
    Ok((bytes, entries, tombs, skips))
}

/// K-way merge of the sorted runs `src_gens` (newest first — the
/// first source holding a key wins) into the run `out_gen`, restricted
/// to keys in `[lo, hi)` (`None` = unbounded on that side).
/// Tombstones are dropped only when `annihilate`.
fn merge_runs_range(
    dir: &Path,
    src_gens: &[u64],
    out_gen: u64,
    lo: Option<&[u8]>,
    hi: Option<&[u8]>,
    annihilate: bool,
    resume: bool,
    backend: &Arc<dyn IndexBackend>,
) -> Result<(u64, u64, u64)> {
    let logs: Vec<SortedVLog> = src_gens
        .iter()
        .map(|&g| SortedVLog::open(&sorted_path(dir, g)))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!logs.is_empty(), "merge with no sources");
    // The merged run covers up to the newest input's snapshot point.
    let (last_term, last_index) = (logs[0].last_term, logs[0].last_index);
    let out_path = sorted_path(dir, out_gen);
    let mut w = open_writer(&out_path, resume, last_term, last_index)?;
    let resume_after: Option<Vec<u8>> = w.last_key().map(|k| k.to_vec());

    /// Pull the next entry of one source (error-propagating).
    fn next_entry<I: Iterator<Item = Result<(u64, VEntry)>>>(
        it: &mut I,
    ) -> Result<Option<VEntry>> {
        match it.next() {
            None => Ok(None),
            Some(r) => Ok(Some(r?.1)),
        }
    }

    // A head at or past `hi` exhausts its source (the file is sorted).
    let clamp = |h: Option<VEntry>| match (&h, hi) {
        (Some(e), Some(hi)) if e.key.as_slice() >= hi => None,
        _ => h,
    };

    // Owned per-source heads instead of Peekable: comparisons borrow
    // the heads, so picking a winner costs zero key clones per output
    // entry even at bottom-level merge sizes.  A partition (`lo` set)
    // seeks each source near `lo` via its sparse index samples, then
    // skips the few sample-granularity entries below it.
    let mut iters: Vec<_> = Vec::with_capacity(logs.len());
    for (i, l) in logs.iter().enumerate() {
        match lo {
            None => iters.push(l.iter()),
            Some(lo) => {
                let idx = HashIndex::load(&index_path(dir, src_gens[i]))
                    .context("merge partition source index")?;
                iters.push(l.iter_from(idx.scan_start(lo)));
            }
        }
    }
    let mut heads: Vec<Option<VEntry>> = Vec::with_capacity(iters.len());
    for it in &mut iters {
        let mut h = next_entry(it)?;
        if let Some(lo) = lo {
            while h.as_ref().is_some_and(|e| e.key.as_slice() < lo) {
                h = next_entry(it)?;
            }
        }
        heads.push(clamp(h));
    }
    loop {
        // Smallest key across sources; ties go to the newest (lowest
        // source position), which then swallows the key everywhere.
        let mut win: Option<usize> = None;
        for (i, h) in heads.iter().enumerate() {
            if let Some(e) = h {
                let better = match win {
                    None => true,
                    Some(w) => e.key < heads[w].as_ref().expect("winner head").key,
                };
                if better {
                    win = Some(i);
                }
            }
        }
        let Some(wi) = win else { break };
        let e = heads[wi].take().expect("winner head");
        for (i, it) in iters.iter_mut().enumerate() {
            if i == wi {
                continue;
            }
            // Superseded by a newer run.
            while heads[i].as_ref().is_some_and(|h| h.key == e.key) {
                heads[i] = clamp(next_entry(it)?);
            }
        }
        heads[wi] = clamp(next_entry(&mut iters[wi])?);
        if annihilate && e.value.is_none() {
            continue;
        }
        if resume_after.as_deref().is_some_and(|ra| e.key.as_slice() <= ra) {
            continue;
        }
        w.add(&e)?;
    }
    seal_run(dir, out_gen, w, backend)
}

/// Serial (single-output) level merge — the reference semantics every
/// partitioned merge must reproduce.
fn merge_runs(
    dir: &Path,
    src_gens: &[u64],
    out_gen: u64,
    annihilate: bool,
    resume: bool,
    backend: &Arc<dyn IndexBackend>,
) -> Result<(u64, u64, u64)> {
    merge_runs_range(dir, src_gens, out_gen, None, None, annihilate, resume, backend)
}

/// Number of key-range partitions for a merge over `total_bytes` of
/// source data.  Derived only from immutable sealed-file sizes, so the
/// count is identical on resume and independent of worker config.
fn partition_count(total_bytes: u64, partition_bytes: u64) -> usize {
    if partition_bytes == 0 || partition_bytes == u64::MAX {
        return 1;
    }
    (total_bytes.div_ceil(partition_bytes) as usize).clamp(1, MAX_PARTS)
}

/// Key-range separators for a `k`-way partitioned merge, drawn from
/// the source runs' sparse index samples (durable with the sealed
/// runs, so a resumed merge reconstructs the identical plan).  May
/// return fewer than `k - 1` bounds when the samples cannot support
/// `k` distinct non-empty ranges.
fn partition_bounds(dir: &Path, src_gens: &[u64], k: usize) -> Result<Vec<Vec<u8>>> {
    if k <= 1 {
        return Ok(Vec::new());
    }
    let mut samples: Vec<Vec<u8>> = Vec::new();
    for &g in src_gens {
        let idx = HashIndex::load(&index_path(dir, g)).context("partition bounds index")?;
        samples.extend(idx.sample_keys().map(|key| key.to_vec()));
    }
    samples.sort_unstable();
    samples.dedup();
    let mut bounds: Vec<Vec<u8>> = Vec::with_capacity(k - 1);
    for j in 1..k {
        let idx = (j * samples.len()) / k;
        if idx == 0 {
            continue; // a bound at the global min key yields an empty part
        }
        bounds.push(samples[idx].clone());
    }
    bounds.dedup();
    Ok(bounds)
}

/// Execute a level merge as `out_gens.len()` key-range partitions on
/// the shared GC [`pool`], at most `workers` in flight.  Partition `j`
/// writes keys in `[bounds[j - 1], bounds[j])`; the concatenation of
/// the outputs is logically identical to the serial [`merge_runs`]
/// output (same sources, same winner rule, disjoint ranges).  Returns
/// `(bytes, entries, tombstones)` per partition in key order.
fn merge_runs_partitioned(
    dir: &Path,
    src_gens: &[u64],
    out_gens: &[u64],
    bounds: &[Vec<u8>],
    annihilate: bool,
    resume: bool,
    backend: &Arc<dyn IndexBackend>,
    workers: usize,
) -> Result<Vec<(u64, u64, u64)>> {
    anyhow::ensure!(out_gens.len() == bounds.len() + 1, "partition plan shape");
    if out_gens.len() == 1 {
        let r =
            merge_runs_range(dir, src_gens, out_gens[0], None, None, annihilate, resume, backend)?;
        return Ok(vec![r]);
    }
    let tasks: Vec<_> = out_gens
        .iter()
        .enumerate()
        .map(|(j, &out)| {
            let dir = dir.to_path_buf();
            let srcs = src_gens.to_vec();
            let lo = (j > 0).then(|| bounds[j - 1].clone());
            let hi = bounds.get(j).cloned();
            let backend = backend.clone();
            move || {
                merge_runs_range(
                    &dir,
                    &srcs,
                    out,
                    lo.as_deref(),
                    hi.as_deref(),
                    annihilate,
                    resume,
                    &backend,
                )
                .with_context(|| format!("merge partition {j} (gen {out})"))
            }
        })
        .collect();
    pool::shared().run_parallel(workers, tasks).into_iter().collect()
}

/// The `GC_MERGE` flag file: a decoupled level-merge job in flight.
pub const MERGE_JOB_FILE: &str = "GC_MERGE";

/// One decoupled level-merge job: everything needed to execute,
/// resume, and commit the merge independently of the GC cycle that
/// scheduled it.  Persisted as [`MERGE_JOB_FILE`] before the first
/// byte is written, so a crash mid-merge resumes the *identical* plan
/// (same sources, bounds and output gens ⇒ byte-identical outputs)
/// even if the partitioning config changed across the restart.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergeJob {
    /// Level being merged into `level + 1`.
    pub level: usize,
    /// Sources in read-precedence order (level runs, then next-level).
    pub srcs: Vec<u64>,
    /// Partition outputs in ascending key order.
    pub out_gens: Vec<u64>,
    /// Key-range separators between adjacent outputs
    /// (`out_gens.len() - 1`).
    pub bounds: Vec<Vec<u8>>,
    pub annihilate: bool,
    /// Snapshot point of the newest source (resume header gate).
    pub last_index: u64,
    pub last_term: u64,
    /// Level stack once this job commits.
    pub stack_after: Vec<Vec<u64>>,
    /// Partition groups once this job commits.
    pub parts_after: Vec<PartitionGroup>,
}

impl MergeJob {
    pub fn save(&self, dir: &Path) -> Result<()> {
        let mut e = Encoder::with_capacity(128);
        e.varint(self.level as u64);
        e.varint(self.srcs.len() as u64);
        for g in &self.srcs {
            e.u64(*g);
        }
        e.varint(self.out_gens.len() as u64);
        for g in &self.out_gens {
            e.u64(*g);
        }
        for b in &self.bounds {
            e.len_bytes(b);
        }
        e.u8(self.annihilate as u8).u64(self.last_index).u64(self.last_term);
        encode_levels(&mut e, &self.stack_after);
        encode_partitions(&mut e, &self.parts_after);
        save_framed(dir, MERGE_JOB_FILE, &e.into_vec())
    }

    pub fn load(dir: &Path) -> Result<Option<Self>> {
        let Some(body) = load_framed(dir, MERGE_JOB_FILE)? else {
            return Ok(None);
        };
        let mut d = Decoder::new(&body);
        let level = d.varint()? as usize;
        let nsrcs = d.varint()? as usize;
        let mut srcs = Vec::with_capacity(nsrcs);
        for _ in 0..nsrcs {
            srcs.push(d.u64()?);
        }
        let nouts = d.varint()? as usize;
        anyhow::ensure!(nouts >= 1, "merge job without outputs");
        let mut out_gens = Vec::with_capacity(nouts);
        for _ in 0..nouts {
            out_gens.push(d.u64()?);
        }
        let mut bounds = Vec::with_capacity(nouts - 1);
        for _ in 0..nouts - 1 {
            bounds.push(d.len_bytes()?.to_vec());
        }
        let annihilate = d.u8()? != 0;
        let last_index = d.u64()?;
        let last_term = d.u64()?;
        let stack_after = decode_levels(&mut d)?;
        let parts_after = decode_partitions(&mut d)?;
        Ok(Some(Self {
            level,
            srcs,
            out_gens,
            bounds,
            annihilate,
            last_index,
            last_term,
            stack_after,
            parts_after,
        }))
    }

    pub fn clear(dir: &Path) -> Result<()> {
        match std::fs::remove_file(dir.join(MERGE_JOB_FILE)) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }

    /// Execute the merge (blocking the calling thread; partitions fan
    /// out to the shared GC pool).  Returns per-partition `(bytes,
    /// entries, tombstones)` in key order.
    pub fn execute(
        &self,
        dir: &Path,
        resume: bool,
        backend: &Arc<dyn IndexBackend>,
        workers: usize,
    ) -> Result<Vec<(u64, u64, u64)>> {
        merge_runs_partitioned(
            dir,
            &self.srcs,
            &self.out_gens,
            &self.bounds,
            self.annihilate,
            resume,
            backend,
            workers,
        )
        .with_context(|| format!("merge level {} -> {}", self.level, self.level + 1))
    }
}

/// The budget planner's next maintenance action for a committed stack.
#[derive(Debug)]
pub enum GcStep {
    /// Every level is within budget.
    Done,
    /// Metadata-only slide of an over-budget single-run level into the
    /// (empty) next level.
    Trivial { stack_after: Vec<Vec<u64>> },
    /// A rewrite merge, packaged as an independently committable job.
    Merge(Box<MergeJob>),
}

/// Logical runs in a level's flat gen list: singletons plus partition
/// groups (a group's sub-runs together count as one run).
fn logical_run_count(level: &[u64], partitions: &[PartitionGroup]) -> usize {
    let mut n = 0usize;
    let mut seen: Vec<usize> = Vec::new();
    for g in level {
        match partitions.iter().position(|p| p.gens.contains(g)) {
            None => n += 1,
            Some(gi) if !seen.contains(&gi) => {
                seen.push(gi);
                n += 1;
            }
            Some(_) => {}
        }
    }
    n
}

/// Find the shallowest over-budget level and decide its maintenance
/// step — the single planning rule shared by the in-cycle cascade
/// ([`run_gc`]) and the engine's decoupled background merge jobs, so
/// both paths produce the identical (resumable) plan from a committed
/// stack.  Planning inputs are all immutable once sealed: run file
/// sizes, sparse index samples, and the recorded tombstone counts.
pub fn plan_step(
    dir: &Path,
    stack: &[Vec<u64>],
    partitions: &[PartitionGroup],
    run_tombstones: &BTreeMap<u64, u64>,
    level0_bytes: u64,
    fanout: u64,
    partition_bytes: u64,
    next_gen: u64,
) -> Result<GcStep> {
    let run_size =
        |gen: u64| -> u64 { std::fs::metadata(sorted_path(dir, gen)).map_or(0, |m| m.len()) };
    for i in 0..stack.len() {
        let size: u64 = stack[i].iter().map(|&g| run_size(g)).sum();
        if size <= level_budget(level0_bytes, fanout, i) {
            continue;
        }
        let next_empty = stack.get(i + 1).is_none_or(|l| l.is_empty());
        if next_empty && logical_run_count(&stack[i], partitions) <= 1 {
            let becomes_bottom =
                stack.get(i + 2..).is_none_or(|rest| rest.iter().all(|l| l.is_empty()));
            let run_tombs: u64 = stack[i]
                .iter()
                .map(|g| run_tombstones.get(g).copied().unwrap_or(1))
                .sum();
            if !(becomes_bottom && run_tombs > 0) {
                // Trivial move: a single over-budget (logical) run with
                // nothing at the next level slides down — metadata
                // only, no rewrite; partition-group membership is by
                // gen, so a partitioned run slides intact.  Tombstone-
                // free runs take this path even when the slide lands
                // them at the stack bottom.
                let mut after = stack.to_vec();
                let runs = std::mem::take(&mut after[i]);
                if i + 1 >= after.len() {
                    after.push(Vec::new());
                }
                after[i + 1] = runs;
                while after.last().is_some_and(|l| l.is_empty()) {
                    after.pop();
                }
                return Ok(GcStep::Trivial { stack_after: after });
            }
            // A tombstone-carrying run about to become the new stack
            // bottom: fall through to the merge below, which rewrites
            // it with `annihilate` so its tombstones stop wasting
            // space (they mask nothing down there).
        }
        let mut srcs = stack[i].clone();
        if let Some(next) = stack.get(i + 1) {
            srcs.extend(next.iter().copied());
        }
        // Tombstones annihilate only when the output becomes the
        // bottom of the stack.
        let annihilate = stack.get(i + 2..).is_none_or(|rest| rest.iter().all(|l| l.is_empty()));
        let total: u64 = srcs.iter().map(|&g| run_size(g)).sum();
        let k = partition_count(total, partition_bytes);
        let bounds = partition_bounds(dir, &srcs, k)?;
        let out_gens: Vec<u64> = (0..bounds.len() as u64 + 1).map(|j| next_gen + j).collect();
        // The merged run covers up to the newest input's snapshot point.
        let newest = SortedVLog::open(&sorted_path(dir, srcs[0]))?;
        let mut after = stack.to_vec();
        after[i] = Vec::new();
        if i + 1 >= after.len() {
            after.push(Vec::new());
        }
        after[i + 1] = out_gens.clone();
        while after.last().is_some_and(|l| l.is_empty()) {
            after.pop();
        }
        let live: std::collections::HashSet<u64> = after.iter().flatten().copied().collect();
        let mut parts_after: Vec<PartitionGroup> = partitions
            .iter()
            .filter(|p| p.gens.iter().all(|g| live.contains(g)))
            .cloned()
            .collect();
        if out_gens.len() > 1 {
            parts_after.push(PartitionGroup { gens: out_gens.clone(), bounds: bounds.clone() });
        }
        return Ok(GcStep::Merge(Box::new(MergeJob {
            level: i,
            srcs,
            out_gens,
            bounds,
            annihilate,
            last_index: newest.last_index,
            last_term: newest.last_term,
            stack_after: after,
            parts_after,
        })));
    }
    Ok(GcStep::Done)
}

/// Flush the frozen epochs into the L0 run and return the cycle's
/// [`GcOutput`] *without* performing any level merges — the decoupled
/// engine path: the cycle commits (epochs reclaim, put path unblocks)
/// as soon as this lands, and over-budget merges become independently
/// scheduled [`MergeJob`]s.  Deterministic given `GcInputs`, so crash
/// recovery simply re-runs it with `resume = true`.
pub fn run_flush(inp: &GcInputs) -> Result<GcOutput> {
    let t0 = std::time::Instant::now();
    // The flush run may annihilate tombstones only if the stack is
    // empty (it becomes the bottom level).
    let stack_empty = inp.stack.iter().all(|l| l.is_empty());
    let (flush_bytes, entries, flush_tombs, skip_offsets) = flush_epochs(inp, stack_empty)?;
    let mut stack: Vec<Vec<u64>> = inp.stack.clone();
    if stack.is_empty() {
        stack.push(Vec::new());
    }
    stack[0].insert(0, inp.out_gen);
    Ok(GcOutput {
        gen: inp.out_gen,
        entries,
        flush_bytes,
        merge_bytes: 0,
        bytes_written: flush_bytes,
        merges: 0,
        levels: stack,
        written_gens: vec![inp.out_gen],
        run_tombstones: vec![(inp.out_gen, flush_tombs)],
        skip_offsets,
        last_index: inp.last_index,
        last_term: inp.last_term,
        wall_ms: t0.elapsed().as_millis() as u64,
        index_backend: inp.backend.name(),
        partitions: inp.partitions.clone(),
        parts: 0,
        is_merge_job: false,
    })
}

/// Run one GC cycle to completion: flush the frozen epochs into a new
/// L0 run, then merge any level that exceeds its budget into the next
/// one ([`plan_step`] repeated until every level fits — the classic
/// leveled cascade).  Deterministic given `GcInputs`, so crash
/// recovery simply re-runs it with `resume = true`: the plan depends
/// only on sealed-file sizes and index samples, and every partition
/// output resumes from its own partial file.
pub fn run_gc(inp: &GcInputs) -> Result<GcOutput> {
    let t0 = std::time::Instant::now();
    let mut out = run_flush(inp)?;
    let mut stack = out.levels.clone();
    let mut partitions = inp.partitions.clone();
    // Known tombstone counts: the committed stack's plus every run
    // this cycle writes.  Runs absent from the map read as "unknown"
    // and are conservatively treated as tombstone-carrying.
    let mut tombs = inp.run_tombstones.clone();
    tombs.insert(inp.out_gen, out.run_tombstones[0].1);
    let mut next_gen = inp.out_gen + 1;
    loop {
        let step = plan_step(
            &inp.dir,
            &stack,
            &partitions,
            &tombs,
            inp.level0_bytes,
            inp.fanout,
            inp.partition_bytes,
            next_gen,
        )?;
        match step {
            GcStep::Done => break,
            GcStep::Trivial { stack_after } => stack = stack_after,
            GcStep::Merge(job) => {
                let parts = job.execute(&inp.dir, inp.resume, &inp.backend, inp.workers)?;
                for (&gen, &(b, _, t)) in job.out_gens.iter().zip(parts.iter()) {
                    out.merge_bytes += b;
                    out.written_gens.push(gen);
                    tombs.insert(gen, t);
                    out.run_tombstones.push((gen, t));
                }
                out.merges += 1;
                out.parts = out.parts.max(job.out_gens.len() as u64);
                next_gen = next_gen.max(job.out_gens.iter().max().expect("outputs") + 1);
                stack = job.stack_after;
                partitions = job.parts_after;
            }
        }
    }
    while stack.last().is_some_and(|l| l.is_empty()) {
        stack.pop();
    }
    out.levels = stack;
    out.partitions = partitions;
    out.bytes_written = out.flush_bytes + out.merge_bytes;
    out.wall_ms = t0.elapsed().as_millis() as u64;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::levels::LeveledStorage;
    use super::*;
    use crate::vlog::VLog;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("nezha-gc-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn write_epoch_file(dir: &Path, epoch: u32, entries: &[VEntry]) -> PathBuf {
        let p = dir.join(format!("raft-{epoch:06}.vlog"));
        let mut v = VLog::open(&p).unwrap();
        for e in entries {
            v.append(e).unwrap();
        }
        v.sync().unwrap();
        p
    }

    fn write_epoch(dir: &Path, entries: &[VEntry]) -> PathBuf {
        write_epoch_file(dir, 0, entries)
    }

    fn inputs(
        dir: &Path,
        vlog: PathBuf,
        stack: Vec<Vec<u64>>,
        gen: u64,
        last_index: u64,
    ) -> GcInputs {
        GcInputs {
            frozen: vec![EpochSource { epoch: 0, path: vlog, skip_offset: 0 }],
            dir: dir.to_path_buf(),
            out_gen: gen,
            stack,
            run_tombstones: Default::default(),
            min_index: 0,
            last_index,
            last_term: 1,
            level0_bytes: u64::MAX, // no merges unless a test lowers it
            fanout: 10,
            partitions: Vec::new(),
            partition_bytes: u64::MAX, // single-partition merges by default
            workers: 1,
            resume: false,
            backend: Arc::new(RustBackend),
        }
    }

    fn open_stack(dir: &Path, out: &GcOutput) -> LeveledStorage {
        LeveledStorage::open_partitioned(dir, &out.levels, &out.partitions).unwrap()
    }

    #[test]
    fn first_cycle_sorts_and_dedups() {
        let dir = tmpdir("first");
        let vlog = write_epoch(
            &dir,
            &[
                VEntry::put(1, 1, "b", "1"),
                VEntry::put(1, 2, "a", "1"),
                VEntry::put(1, 3, "b", "2"), // overwrites
                VEntry::put(1, 4, "c", "1"),
                VEntry::delete(1, 5, "c"), // tombstone annihilates (bottom)
            ],
        );
        let out = run_gc(&inputs(&dir, vlog, vec![], 1, 5)).unwrap();
        assert_eq!(out.entries, 2);
        assert_eq!(out.levels, vec![vec![1]]);
        assert_eq!(out.bytes_written, out.flush_bytes);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        assert_eq!(fs.log.last_index, 5);
        assert_eq!(fs.get(b"b").unwrap().unwrap().value, Some(b"2".to_vec()));
        assert_eq!(fs.get(b"a").unwrap().unwrap().value, Some(b"1".to_vec()));
        assert!(fs.get(b"c").unwrap().is_none());
        // Scan is ordered.
        let scan = fs.scan(b"", b"zzz", 10).unwrap();
        assert_eq!(scan.len(), 2);
        assert_eq!(scan[0].key, b"a".to_vec());
    }

    #[test]
    fn second_cycle_stacks_a_new_run() {
        let dir = tmpdir("second");
        let v1 = write_epoch(
            &dir,
            &[
                VEntry::put(1, 1, "a", "old"),
                VEntry::put(1, 2, "b", "old"),
                VEntry::put(1, 3, "d", "old"),
            ],
        );
        let out1 = run_gc(&inputs(&dir, v1, vec![], 1, 3)).unwrap();
        // Second epoch: update b, delete d, add c.
        let p2 = dir.join("raft-000001.vlog");
        let mut v = VLog::open(&p2).unwrap();
        v.append(&VEntry::put(2, 4, "b", "new")).unwrap();
        v.append(&VEntry::delete(2, 5, "d")).unwrap();
        v.append(&VEntry::put(2, 6, "c", "new")).unwrap();
        v.sync().unwrap();
        let mut inp = inputs(&dir, p2, out1.levels.clone(), 2, 6);
        inp.min_index = 3;
        let out = run_gc(&inp).unwrap();
        // No merge: the new run stacks on L0, tombstone RETAINED
        // (there is an older run below it).
        assert_eq!(out.levels, vec![vec![2, 1]]);
        assert_eq!(out.entries, 3); // b, c, d-tombstone
        assert_eq!(out.merges, 0);
        let stack = open_stack(&dir, &out);
        assert_eq!(stack.get(b"a").unwrap().unwrap().value, Some(b"old".to_vec()));
        assert_eq!(stack.get(b"b").unwrap().unwrap().value, Some(b"new".to_vec()));
        assert_eq!(stack.get(b"c").unwrap().unwrap().value, Some(b"new".to_vec()));
        // Tombstone masks the older run's value.
        assert_eq!(stack.get(b"d").unwrap().unwrap().value, None);
    }

    #[test]
    fn over_budget_level_merges_and_annihilates_at_bottom() {
        let dir = tmpdir("merge");
        let v1 = write_epoch(
            &dir,
            &[
                VEntry::put(1, 1, "a", "old"),
                VEntry::put(1, 2, "b", "old"),
                VEntry::put(1, 3, "d", "old"),
            ],
        );
        let out1 = run_gc(&inputs(&dir, v1, vec![], 1, 3)).unwrap();
        let p2 = dir.join("raft-000001.vlog");
        let mut v = VLog::open(&p2).unwrap();
        v.append(&VEntry::put(2, 4, "b", "new")).unwrap();
        v.append(&VEntry::delete(2, 5, "d")).unwrap();
        v.sync().unwrap();
        let mut inp = inputs(&dir, p2, out1.levels.clone(), 2, 5);
        inp.min_index = 3;
        inp.level0_bytes = 1; // force the L0 merge
        inp.fanout = 1 << 20; // ...but keep L1 inside its budget
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.merges, 1);
        assert!(out.merge_bytes > 0);
        assert_eq!(out.levels, vec![vec![], vec![3]]);
        assert_eq!(out.written_gens, vec![2, 3]);
        let stack = open_stack(&dir, &out);
        assert_eq!(stack.get(b"a").unwrap().unwrap().value, Some(b"old".to_vec()));
        assert_eq!(stack.get(b"b").unwrap().unwrap().value, Some(b"new".to_vec()));
        // The merge output is the bottom: the tombstone annihilated.
        assert!(stack.get(b"d").unwrap().is_none());
        let bottom = FinalStorage::open(&dir, 3).unwrap();
        assert_eq!(bottom.index.entry_count, 2); // a, b — no tombstone frame
    }

    #[test]
    fn tombstones_retained_until_bottom_level() {
        let dir = tmpdir("tomblevels");
        // Bottom run with the key.
        let v1 = write_epoch(&dir, &[VEntry::put(1, 1, "k", "v"), VEntry::put(1, 2, "z", "zz")]);
        let out1 = run_gc(&inputs(&dir, v1, vec![], 1, 2)).unwrap();
        // Delete lands in a new upper run; the tombstone must survive.
        let p2 = dir.join("raft-000001.vlog");
        let mut v = VLog::open(&p2).unwrap();
        v.append(&VEntry::delete(1, 3, "k")).unwrap();
        v.sync().unwrap();
        let mut inp = inputs(&dir, p2, out1.levels.clone(), 2, 3);
        inp.min_index = 2;
        let out2 = run_gc(&inp).unwrap();
        let l0 = FinalStorage::open(&dir, 2).unwrap();
        let tomb = l0.get(b"k").unwrap().expect("tombstone frame retained in L0");
        assert_eq!(tomb.value, None);
        let stack = open_stack(&dir, &out2);
        assert_eq!(stack.get(b"k").unwrap().unwrap().value, None);
        assert_eq!(stack.get(b"z").unwrap().unwrap().value, Some(b"zz".to_vec()));
        // A forced full merge annihilates it.
        let p3 = dir.join("raft-000002.vlog");
        let mut v = VLog::open(&p3).unwrap();
        v.append(&VEntry::put(1, 4, "w", "ww")).unwrap();
        v.sync().unwrap();
        let mut inp = inputs(&dir, p3, out2.levels.clone(), 3, 4);
        inp.min_index = 3;
        inp.level0_bytes = 1;
        inp.fanout = 2;
        let out3 = run_gc(&inp).unwrap();
        let stack = open_stack(&dir, &out3);
        assert!(stack.get(b"k").unwrap().is_none(), "annihilated at bottom");
        assert_eq!(stack.get(b"w").unwrap().unwrap().value, Some(b"ww".to_vec()));
        assert_eq!(stack.get(b"z").unwrap().unwrap().value, Some(b"zz".to_vec()));
    }

    #[test]
    fn uncommitted_tail_excluded() {
        let dir = tmpdir("tail");
        let vlog = write_epoch(
            &dir,
            &[
                VEntry::put(1, 1, "a", "1"),
                VEntry::put(1, 2, "b", "1"),
                VEntry::put(1, 3, "x", "uncommitted"),
            ],
        );
        // last_index = 2: entry 3 must not appear.
        run_gc(&inputs(&dir, vlog, vec![], 1, 2)).unwrap();
        let fs = FinalStorage::open(&dir, 1).unwrap();
        assert!(fs.get(b"x").unwrap().is_none());
        assert!(fs.get(b"a").unwrap().is_some());
    }

    #[test]
    fn multi_epoch_inputs_compact_retained_tails() {
        let dir = tmpdir("multiepoch");
        // Epoch 0: indexes 1..=4, but the first cycle snapshotted only
        // up to 2 (backlog) — 3 and 4 ride along into the next cycle.
        let v0 = write_epoch_file(
            &dir,
            0,
            &[
                VEntry::put(1, 1, "a", "1"),
                VEntry::put(1, 2, "b", "1"),
                VEntry::put(1, 3, "c", "tail"),
                VEntry::put(1, 4, "a", "tail-overwrite"),
            ],
        );
        let out1 = run_gc(&inputs(&dir, v0.clone(), vec![], 1, 2)).unwrap();
        assert_eq!(out1.entries, 2); // a, b
        // Epoch 1: index 5.
        let v1 = write_epoch_file(&dir, 1, &[VEntry::put(1, 5, "d", "1")]);
        let mut inp = inputs(&dir, v1.clone(), out1.levels.clone(), 2, 5);
        inp.frozen = vec![
            EpochSource { epoch: 0, path: v0, skip_offset: 0 },
            EpochSource { epoch: 1, path: v1, skip_offset: 0 },
        ];
        inp.min_index = 2;
        let out2 = run_gc(&inp).unwrap();
        assert_eq!(out2.entries, 3); // c, a-overwrite, d
        let stack = open_stack(&dir, &out2);
        assert_eq!(stack.get(b"a").unwrap().unwrap().value, Some(b"tail-overwrite".to_vec()));
        assert_eq!(stack.get(b"b").unwrap().unwrap().value, Some(b"1".to_vec()));
        assert_eq!(stack.get(b"c").unwrap().unwrap().value, Some(b"tail".to_vec()));
        assert_eq!(stack.get(b"d").unwrap().unwrap().value, Some(b"1".to_vec()));
    }

    #[test]
    fn resume_continues_from_interrupt_point() {
        let dir = tmpdir("resume");
        let entries: Vec<VEntry> = (0..100u64)
            .map(|i| VEntry::put(1, i + 1, format!("key{i:04}"), format!("v{i}")))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        // Simulate an interrupted first run: write a partial sorted
        // file by hand (first 30 keys).
        {
            let mut w = SortedVLogWriter::create(&sorted_path(&dir, 1), 1, 100).unwrap();
            for e in entries.iter().take(30) {
                w.add(e).unwrap();
            }
            w.finish().unwrap();
        }
        let mut inp = inputs(&dir, vlog, vec![], 1, 100);
        inp.resume = true;
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.entries, 100);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        for i in (0..100u64).step_by(9) {
            let k = format!("key{i:04}");
            assert_eq!(
                fs.get(k.as_bytes()).unwrap().unwrap().value,
                Some(format!("v{i}").into_bytes()),
                "{k}"
            );
        }
        // No duplicates: scan count matches.
        assert_eq!(fs.scan(b"", b"z", 1000).unwrap().len(), 100);
    }

    /// Crash/resume mid-LEVEL-MERGE: interrupt the merge output
    /// mid-frame and re-run the cycle; the finished files must be
    /// byte-identical to an uninterrupted cycle.
    #[test]
    fn resume_mid_merge_is_byte_identical() {
        let epoch0: Vec<VEntry> = (0..80u64)
            .map(|i| {
                if i % 9 == 4 {
                    VEntry::delete(1, i + 1, format!("key{:04}", i * 3 % 80))
                } else {
                    VEntry::put(1, i + 1, format!("key{:04}", i * 3 % 80), format!("v{i}"))
                }
            })
            .collect();
        let epoch1: Vec<VEntry> = (0..40u64)
            .map(|i| VEntry::put(1, 81 + i, format!("key{:04}", 40 + i), format!("w{i}")))
            .collect();
        let cycle2 = |dir: &Path| -> GcInputs {
            let v1 = write_epoch_file(dir, 1, &epoch1);
            let mut inp = inputs(dir, v1, vec![vec![1]], 2, 120);
            inp.min_index = 80;
            inp.level0_bytes = 1; // force the merge
            inp.fanout = 1 << 20;
            inp
        };
        // Reference: uninterrupted run.
        let ref_dir = tmpdir("merge-ref");
        let v0 = write_epoch_file(&ref_dir, 0, &epoch0);
        run_gc(&inputs(&ref_dir, v0, vec![], 1, 80)).unwrap();
        let ref_out = run_gc(&cycle2(&ref_dir)).unwrap();
        assert_eq!(ref_out.merges, 1);
        let merged_gen = *ref_out.written_gens.last().unwrap();
        let ref_bytes = std::fs::read(sorted_path(&ref_dir, merged_gen)).unwrap();

        // Crashed run: flush completed, merge output cut mid-frame.
        let dir = tmpdir("merge-crash");
        let v0 = write_epoch_file(&dir, 0, &epoch0);
        run_gc(&inputs(&dir, v0, vec![], 1, 80)).unwrap();
        let mut inp = cycle2(&dir);
        run_gc(&inp).unwrap();
        let full = std::fs::read(sorted_path(&dir, merged_gen)).unwrap();
        assert_eq!(full, ref_bytes, "precondition: deterministic outputs");
        std::fs::write(sorted_path(&dir, merged_gen), &full[..full.len() * 2 / 3]).unwrap();
        let _ = std::fs::remove_file(index_path(&dir, merged_gen));
        inp.resume = true;
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.levels, ref_out.levels);
        let resumed = std::fs::read(sorted_path(&dir, merged_gen)).unwrap();
        assert_eq!(resumed, ref_bytes, "resumed merge diverged");
        // And lookups agree with the reference.
        let a = LeveledStorage::open(&dir, &out.levels).unwrap();
        let b = LeveledStorage::open(&ref_dir, &ref_out.levels).unwrap();
        for i in 0..80u64 {
            let k = format!("key{i:04}");
            assert_eq!(
                a.get(k.as_bytes()).unwrap().map(|e| e.value),
                b.get(k.as_bytes()).unwrap().map(|e| e.value),
                "{k}"
            );
        }
    }

    #[test]
    fn final_storage_multi_get_matches_get() {
        let dir = tmpdir("mget");
        let entries: Vec<VEntry> = (0..400u64)
            .map(|i| VEntry::put(1, i + 1, format!("key{i:04}"), format!("v{i}")))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        run_gc(&inputs(&dir, vlog, vec![], 1, 400)).unwrap();
        let fs = FinalStorage::open(&dir, 1).unwrap();
        // Unsorted request order, present and absent keys mixed.
        let keys: Vec<Vec<u8>> = (0..500u64)
            .rev()
            .step_by(7)
            .map(|i| format!("key{i:04}").into_bytes())
            .collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
        let batched = fs.multi_get(&refs).unwrap();
        assert_eq!(batched.len(), keys.len());
        for (k, b) in keys.iter().zip(&batched) {
            assert_eq!(*b, fs.get(k).unwrap(), "{}", String::from_utf8_lossy(k));
        }
    }

    #[test]
    fn leveled_multi_get_matches_leveled_get() {
        let dir = tmpdir("lmget");
        let v0 = write_epoch(
            &dir,
            &(0..60u64)
                .map(|i| VEntry::put(1, i + 1, format!("key{i:03}"), format!("old{i}")))
                .collect::<Vec<_>>(),
        );
        let out1 = run_gc(&inputs(&dir, v0, vec![], 1, 60)).unwrap();
        let p2 = dir.join("raft-000001.vlog");
        let mut v = VLog::open(&p2).unwrap();
        for i in 0..30u64 {
            if i % 5 == 0 {
                v.append(&VEntry::delete(1, 61 + i, format!("key{:03}", i * 2))).unwrap();
            } else {
                let e = VEntry::put(1, 61 + i, format!("key{:03}", i * 2), format!("new{i}"));
                v.append(&e).unwrap();
            }
        }
        v.sync().unwrap();
        let mut inp = inputs(&dir, p2, out1.levels.clone(), 2, 90);
        inp.min_index = 60;
        let out = run_gc(&inp).unwrap();
        let stack = open_stack(&dir, &out);
        let keys: Vec<Vec<u8>> = (0..70u64).map(|i| format!("key{i:03}").into_bytes()).collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
        let batched = stack.multi_get(&refs).unwrap();
        for (k, b) in refs.iter().zip(batched) {
            let single = stack.get(k).unwrap();
            assert_eq!(
                b.as_ref().map(|e| &e.value),
                single.as_ref().map(|e| &e.value),
                "{}",
                String::from_utf8_lossy(k)
            );
        }
    }

    #[test]
    fn gc_state_flag_roundtrip() {
        let dir = tmpdir("state");
        assert_eq!(GcState::load(&dir).unwrap(), None);
        let st = GcState {
            running: true,
            min_epoch: 2,
            frozen_epoch: 3,
            out_gen: 2,
            min_index: 10,
            last_index: 55,
            last_term: 4,
            stack: vec![vec![7, 5], vec![1]],
            run_tombstones: [(7, 0), (5, 12), (1, 3)].into_iter().collect(),
            partitions: vec![PartitionGroup {
                gens: vec![7, 5],
                bounds: vec![b"m".to_vec()],
            }],
        };
        st.save(&dir).unwrap();
        assert_eq!(GcState::load(&dir).unwrap(), Some(st));
        GcState::clear(&dir).unwrap();
        assert_eq!(GcState::load(&dir).unwrap(), None);
    }

    /// A leveled-but-pre-tombstone-count flag file (stack recorded, no
    /// trailing count map) still decodes; the empty map reads as
    /// "unknown" downstream.
    #[test]
    fn gc_state_decodes_pre_tombstone_count_format() {
        let dir = tmpdir("pretombstate");
        let mut e = Encoder::with_capacity(64);
        e.u8(1).u32(2).u32(3).u64(2).u64(10).u64(55).u64(4);
        let stack = vec![vec![7, 5], vec![1]];
        encode_levels(&mut e, &stack);
        let body = e.into_vec();
        let mut framed = Encoder::with_capacity(body.len() + 4);
        framed.u32(crc32fast::hash(&body)).bytes(&body);
        std::fs::write(dir.join("GC_STATE"), framed.as_slice()).unwrap();
        let st = GcState::load(&dir).unwrap().expect("decodes");
        assert_eq!(st.stack, vec![vec![7, 5], vec![1]]);
        assert!(st.run_tombstones.is_empty());
    }

    /// Upgrade path: a pre-leveled GC_STATE (29-byte body, single
    /// frozen epoch, no stack) still decodes after the format change.
    #[test]
    fn gc_state_decodes_legacy_format() {
        let dir = tmpdir("legacystate");
        let mut e = Encoder::with_capacity(40);
        e.u8(1).u32(3).u64(2).u64(55).u64(4);
        let body = e.into_vec();
        assert_eq!(body.len(), GcState::LEGACY_BODY_LEN);
        let mut framed = Encoder::with_capacity(body.len() + 4);
        framed.u32(crc32fast::hash(&body)).bytes(&body);
        std::fs::write(dir.join("GC_STATE"), framed.as_slice()).unwrap();
        let st = GcState::load(&dir).unwrap().expect("legacy state decodes");
        assert!(st.running);
        assert_eq!(st.min_epoch, 3);
        assert_eq!(st.frozen_epoch, 3);
        assert_eq!(st.out_gen, 2);
        assert_eq!(st.min_index, 0);
        assert_eq!(st.last_index, 55);
        assert_eq!(st.last_term, 4);
        assert!(st.stack.is_empty());
    }

    #[test]
    fn gen_discovery() {
        let dir = tmpdir("gens");
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), None);
        let v = write_epoch(&dir, &[VEntry::put(1, 1, "a", "1")]);
        let out1 = run_gc(&inputs(&dir, v.clone(), vec![], 1, 1)).unwrap();
        run_gc(&inputs(&dir, v, out1.levels.clone(), 2, 1)).unwrap();
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), Some(2));
        assert_eq!(FinalStorage::list_gens(&dir).unwrap(), vec![1, 2]);
        FinalStorage::remove_gen(&dir, 2);
        assert_eq!(FinalStorage::latest_gen(&dir).unwrap(), Some(1));
        // A partial (unsealed) output is invisible to the sealed
        // listing but must be visible to cleanup.
        let w = SortedVLogWriter::create(&sorted_path(&dir, 5), 1, 1).unwrap();
        drop(w);
        assert_eq!(FinalStorage::list_gens(&dir).unwrap(), vec![1]);
        assert_eq!(FinalStorage::list_all_gens(&dir).unwrap(), vec![1, 5]);
    }

    /// Generation reuse after `install_snapshot`: a leftover file from
    /// a different cycle carries a different snapshot point in its
    /// header, so a resume must start the run from scratch instead of
    /// adopting stale (pre-snapshot) content.
    #[test]
    fn open_writer_rejects_stale_file_on_resume() {
        let dir = tmpdir("stale");
        let p = sorted_path(&dir, 1);
        {
            let mut w = SortedVLogWriter::create(&p, 1, 10).unwrap();
            w.add(&VEntry::put(1, 9, "stale", "old")).unwrap();
            w.finish().unwrap();
        }
        // Matching header → genuine resume, keeps the prefix.
        let w = open_writer(&p, true, 1, 10).unwrap();
        assert_eq!(w.last_key(), Some(b"stale".as_slice()));
        drop(w);
        // Different cycle → recreated empty.
        let w = open_writer(&p, true, 2, 20).unwrap();
        assert_eq!(w.last_key(), None);
        let (bytes, _) = w.finish().unwrap();
        assert_eq!(bytes, crate::vlog::sorted::HEADER_LEN);
        let s = SortedVLog::open(&p).unwrap();
        assert_eq!((s.last_term, s.last_index), (2, 20));
    }

    #[test]
    fn large_cycle_roundtrips() {
        let dir = tmpdir("large");
        let entries: Vec<VEntry> = (0..5000u64)
            .map(|i| {
                VEntry::put(1, i + 1, format!("user{:08}", i * 7 % 5000), vec![(i % 251) as u8; 64])
            })
            .collect();
        let vlog = write_epoch(&dir, &entries);
        let out = run_gc(&inputs(&dir, vlog, vec![], 1, 5000)).unwrap();
        assert!(out.entries > 0);
        let fs = FinalStorage::open(&dir, 1).unwrap();
        let all = fs.scan(b"", b"z", 100_000).unwrap();
        assert_eq!(all.len() as u64, out.entries);
        for w in all.windows(2) {
            assert!(w[0].key < w[1].key);
        }
    }

    /// Per-cycle write volume stays bounded by level budgets: with a
    /// fanout-f stack, most cycles only flush; deep merges are
    /// geometrically rare, so no cycle rewrites the whole dataset once
    /// the bottom level exceeds the data added per cycle.
    #[test]
    fn cycle_bytes_bounded_by_budgets() {
        let dir = tmpdir("bounded");
        let mut stack: Vec<Vec<u64>> = vec![];
        let mut tomb_counts: std::collections::BTreeMap<u64, u64> = Default::default();
        let mut next_gen = 1u64;
        let mut index = 0u64;
        let mut total_flush = 0u64;
        let mut flush_only_cycles = 0u32;
        let mut any_merge = false;
        let per_cycle = 40u64;
        for cycle in 0..12u32 {
            let entries: Vec<VEntry> = (0..per_cycle)
                .map(|i| {
                    index += 1;
                    let key = format!("key{:06}", cycle as u64 * per_cycle + i);
                    VEntry::put(1, index, key, vec![7u8; 64])
                })
                .collect();
            let v = write_epoch_file(&dir, cycle, &entries);
            let mut inp = inputs(&dir, v, stack.clone(), next_gen, index);
            inp.min_index = index - per_cycle;
            // L0 holds ~1 flush; level budgets grow 4x.
            inp.level0_bytes = 5 << 10;
            inp.fanout = 4;
            inp.run_tombstones = tomb_counts.clone();
            let out = run_gc(&inp).unwrap();
            for (g, t) in &out.run_tombstones {
                tomb_counts.insert(*g, *t);
            }
            stack = out.levels.clone();
            next_gen = out.written_gens.iter().max().unwrap() + 1;
            total_flush += out.flush_bytes;
            if out.merges == 0 {
                flush_only_cycles += 1;
                // A flush-only cycle writes just the epoch's live data,
                // never a rewrite of older levels.
                assert!(
                    out.bytes_written <= 2 * inp.level0_bytes,
                    "cycle {cycle}: flush-only cycle wrote {} bytes",
                    out.bytes_written
                );
            } else {
                any_merge = true;
            }
            // Cleanup superseded runs like the engine does.
            for g in out.written_gens.iter().chain(inp.stack.iter().flatten()) {
                if !out.levels.iter().flatten().any(|x| x == g) {
                    FinalStorage::remove_gen(&dir, *g);
                }
            }
        }
        let stack_store = LeveledStorage::open(&dir, &stack).unwrap();
        // All 480 distinct keys live.
        for i in (0..480u64).step_by(37) {
            let k = format!("key{i:06}");
            assert!(stack_store.get(k.as_bytes()).unwrap().is_some(), "{k}");
        }
        // The old single-generation GC rewrote the whole dataset every
        // cycle; leveled GC must leave most cycles flush-only, while
        // merges deepen the stack.
        assert!(total_flush > 0);
        assert!(any_merge, "budgets never triggered a merge");
        assert!(
            flush_only_cycles >= 4,
            "only {flush_only_cycles} flush-only cycles — per-cycle work not bounded"
        );
        assert!(stack.len() >= 3, "stack should have deepened: {stack:?}");
    }

    /// Satellite: a cycle records, per retained epoch, the first offset
    /// above its snapshot point; the next cycle seeks straight there.
    /// Proof that the prefix is genuinely not read: corrupt it — the
    /// skipping cycle succeeds while a full read fails on the CRC.
    #[test]
    fn flush_seeks_past_already_compacted_prefix() {
        let dir = tmpdir("prefixskip");
        // One epoch, indexes 1..=10; first cycle covers only 1..=5
        // (apply backlog), so 6..=10 ride along to the next cycle.
        let entries: Vec<VEntry> = (0..10u64)
            .map(|i| VEntry::put(1, i + 1, format!("key{i:02}"), vec![7u8; 64]))
            .collect();
        let vlog = write_epoch(&dir, &entries);
        let out1 = run_gc(&inputs(&dir, vlog.clone(), vec![], 1, 5)).unwrap();
        assert_eq!(out1.entries, 5);
        let (epoch, skip) = out1.skip_offsets[0];
        assert_eq!(epoch, 0);
        assert!(skip > 0, "skip offset for the uncompacted tail");

        // Cycle 2 with the recorded skip compacts exactly the tail.
        let cycle2 = |skip_offset: u64| {
            let mut inp = inputs(&dir, vlog.clone(), out1.levels.clone(), 2, 10);
            inp.frozen[0].skip_offset = skip_offset;
            inp.min_index = 5;
            inp
        };
        let out2 = run_gc(&cycle2(skip)).unwrap();
        assert_eq!(out2.entries, 5, "tail entries 6..=10");
        let reference = std::fs::read(sorted_path(&dir, 2)).unwrap();
        // A fully-covered epoch's next skip is the whole file.
        assert_eq!(out2.skip_offsets[0].1, std::fs::metadata(&vlog).unwrap().len());

        // Corrupt a byte inside the already-compacted prefix.
        let mut bytes = std::fs::read(&vlog).unwrap();
        bytes[(skip / 2) as usize] ^= 0xff;
        std::fs::write(&vlog, &bytes).unwrap();
        // Full re-read trips over the corruption...
        FinalStorage::remove_gen(&dir, 2);
        assert!(run_gc(&cycle2(0)).is_err(), "unskipped read must hit the corrupt prefix");
        // ...while the seek-past cycle never touches those bytes and
        // produces a byte-identical run.
        FinalStorage::remove_gen(&dir, 2);
        let out2b = run_gc(&cycle2(skip)).unwrap();
        assert_eq!(out2b.entries, 5);
        assert_eq!(std::fs::read(sorted_path(&dir, 2)).unwrap(), reference);
    }

    /// Build a hand-made sorted run (sealed through the real path) for
    /// the trivial-move tests below.  Returns its byte size.
    fn build_run(dir: &Path, gen: u64, n: u32, tombstones: u32) -> u64 {
        let mut w = SortedVLogWriter::create(&sorted_path(dir, gen), 1, 1000).unwrap();
        for i in 0..n {
            let e = if i < tombstones {
                VEntry::delete(1, 900 + i as u64, format!("del{i:04}"))
            } else {
                VEntry::put(1, i as u64 + 1, format!("key{i:04}"), vec![9u8; 400])
            };
            w.add(&e).unwrap();
        }
        let backend: Arc<dyn IndexBackend> = Arc::new(RustBackend);
        let (bytes, _, t) = seal_run(dir, gen, w, &backend).unwrap();
        assert_eq!(t, tombstones as u64);
        bytes
    }

    /// Satellite: a tombstone-carrying run whose trivial move would
    /// make it the new stack bottom is rewritten instead — its
    /// tombstones annihilate (they mask nothing below).
    #[test]
    fn trivial_move_to_bottom_annihilates_tombstones() {
        let dir = tmpdir("tombmove");
        let run_bytes = build_run(&dir, 5, 40, 6);
        // L0 budget comfortably holds the flush; L1's (budget × fanout)
        // does not hold run 5, and L2+ are empty — run 5's slide from
        // L1 would land it at the bottom.
        let v = write_epoch(&dir, &[VEntry::put(1, 2000, "zzz-new", "x")]);
        let mut inp = inputs(&dir, v, vec![vec![], vec![5]], 6, 2000);
        inp.level0_bytes = run_bytes / 8;
        inp.fanout = 4; // L1 budget = run_bytes/2 < run_bytes; L2 = 2×run_bytes
        inp.run_tombstones = [(5u64, 6u64)].into_iter().collect();
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.merges, 1, "rewrite instead of a metadata slide");
        let bottom_gen = *out.levels.last().unwrap().first().unwrap();
        assert_ne!(bottom_gen, 5, "run was rewritten under a fresh generation");
        assert!(out.run_tombstones.contains(&(bottom_gen, 0)), "{:?}", out.run_tombstones);
        let bottom = FinalStorage::open(&dir, bottom_gen).unwrap();
        assert!(bottom.get(b"del0002").unwrap().is_none(), "tombstone frame gone");
        assert_eq!(bottom.index.entry_count, 34, "34 live rows, 0 tombstones");
        let stack = LeveledStorage::open(&dir, &out.levels).unwrap();
        assert!(stack.get(b"key0039").unwrap().is_some());
    }

    /// Satellite counterpart: a tombstone-free run still slides to the
    /// bottom as pure metadata — no rewrite, same generation.
    #[test]
    fn tombstone_free_trivial_move_stays_metadata_only() {
        let dir = tmpdir("cleanmove");
        let run_bytes = build_run(&dir, 5, 40, 0);
        let v = write_epoch(&dir, &[VEntry::put(1, 2000, "zzz-new", "x")]);
        let mut inp = inputs(&dir, v, vec![vec![], vec![5]], 6, 2000);
        inp.level0_bytes = run_bytes / 8;
        inp.fanout = 4;
        inp.run_tombstones = [(5u64, 0u64)].into_iter().collect();
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.merges, 0, "tombstone-free run must move without a rewrite");
        assert!(
            out.levels.last().unwrap().contains(&5),
            "same generation slid to the bottom: {:?}",
            out.levels
        );
        // Unknown counts (pre-upgrade manifest) are conservative: the
        // same move with no recorded count rewrites once.
        FinalStorage::remove_gen(&dir, 6);
        let v2 = write_epoch(&dir, &[VEntry::put(1, 2000, "zzz-new", "x")]);
        let mut inp2 = inputs(&dir, v2, vec![vec![], vec![5]], 6, 2000);
        inp2.level0_bytes = run_bytes / 8;
        inp2.fanout = 4;
        let out2 = run_gc(&inp2).unwrap();
        assert_eq!(out2.merges, 1, "unknown count treated as tombstone-carrying");
    }

    #[test]
    fn merge_job_flag_roundtrip() {
        let dir = tmpdir("mergejob");
        assert_eq!(MergeJob::load(&dir).unwrap(), None);
        let bounds = vec![b"g".to_vec(), b"p".to_vec()];
        let job = MergeJob {
            level: 1,
            srcs: vec![9, 7, 3],
            out_gens: vec![10, 11, 12],
            bounds: bounds.clone(),
            annihilate: true,
            last_index: 77,
            last_term: 5,
            stack_after: vec![vec![], vec![10, 11, 12]],
            parts_after: vec![PartitionGroup { gens: vec![10, 11, 12], bounds }],
        };
        job.save(&dir).unwrap();
        assert_eq!(MergeJob::load(&dir).unwrap(), Some(job.clone()));
        // Single-output (unpartitioned) job: no bounds section at all.
        let solo = MergeJob { out_gens: vec![10], bounds: Vec::new(), ..job };
        solo.save(&dir).unwrap();
        assert_eq!(MergeJob::load(&dir).unwrap(), Some(solo));
        MergeJob::clear(&dir).unwrap();
        assert_eq!(MergeJob::load(&dir).unwrap(), None);
    }

    /// Read the logical entry stream of a run sequence (key order
    /// within each run; partition outputs concatenate in key order).
    fn read_entries(dir: &Path, gens: &[u64]) -> Result<Vec<VEntry>> {
        let mut out = Vec::new();
        for &g in gens {
            let log = SortedVLog::open(&sorted_path(dir, g))?;
            for item in log.iter() {
                out.push(item?.1);
            }
        }
        Ok(out)
    }

    /// Tentpole property: for random key distributions, tombstone
    /// mixes and K ∈ {1, 2, 4, 8}, the concatenated outputs of a
    /// partitioned merge are entry-identical to the serial
    /// [`merge_runs`] output over the same sources — the invariant
    /// that lets partition fan-out (and worker count) vary without
    /// changing the committed stack's contents.
    #[test]
    fn partitioned_merge_matches_serial_property() {
        crate::util::prop::check("partitioned-merge-eq-serial", 6, |g| {
            let dir = tmpdir(&format!("partprop{:016x}", g.seed));
            let inner = |g: &mut crate::util::prop::Gen| -> Result<()> {
                let backend: Arc<dyn IndexBackend> = Arc::new(RustBackend);
                // 2-3 overlapping source runs, newest first, sealed
                // through the real path so index samples exist.
                let nsrc = g.usize_in(2..4);
                let src_gens: Vec<u64> = (1..=nsrc as u64).collect();
                for (si, &gen) in src_gens.iter().enumerate() {
                    let mut run: BTreeMap<Vec<u8>, VEntry> = BTreeMap::new();
                    for i in 0..g.usize_in(50..220) {
                        let key = g.key(1..7);
                        let idx = (1000 * (nsrc - si) + i) as u64;
                        let e = if g.chance(0.2) {
                            VEntry::delete(1, idx, key.clone())
                        } else {
                            VEntry::put(1, idx, key.clone(), g.bytes(0..40))
                        };
                        run.insert(key, e);
                    }
                    let mut w = SortedVLogWriter::create(&sorted_path(&dir, gen), 1, 5000)?;
                    for e in run.values() {
                        w.add(e)?;
                    }
                    seal_run(&dir, gen, w, &backend)?;
                }
                let annihilate = g.bool();
                let serial_gen = 100u64;
                merge_runs(&dir, &src_gens, serial_gen, annihilate, false, &backend)?;
                let want = read_entries(&dir, &[serial_gen])?;
                for k in [1usize, 2, 4, 8] {
                    let bounds = partition_bounds(&dir, &src_gens, k)?;
                    anyhow::ensure!(bounds.len() < k, "k={k}: too many bounds");
                    let base = 200 + 10 * k as u64;
                    let out_gens: Vec<u64> =
                        (0..bounds.len() as u64 + 1).map(|j| base + j).collect();
                    let parts = merge_runs_partitioned(
                        &dir,
                        &src_gens,
                        &out_gens,
                        &bounds,
                        annihilate,
                        false,
                        &backend,
                        g.usize_in(1..4),
                    )?;
                    let got = read_entries(&dir, &out_gens)?;
                    anyhow::ensure!(
                        got == want,
                        "k={k} annihilate={annihilate}: {} entries vs serial {}",
                        got.len(),
                        want.len()
                    );
                    let total_entries: u64 = parts.iter().map(|&(_, e, _)| e).sum();
                    anyhow::ensure!(total_entries == want.len() as u64, "k={k}: entry counts");
                }
                Ok(())
            };
            let res = inner(g).map_err(|e| format!("seed {:#x}: {e:#}", g.seed));
            let _ = std::fs::remove_dir_all(&dir);
            res
        });
    }

    /// Crash/resume mid-PARTITIONED-merge: cut one partition's output
    /// mid-frame (and drop its index), re-run the cycle, and require
    /// every partition file byte-identical to an uninterrupted
    /// reference — each partition resumes from its own partial file
    /// while sealed siblings re-verify as no-ops.
    #[test]
    fn resume_mid_partitioned_merge_is_byte_identical() {
        let epoch0: Vec<VEntry> = (0..300u64)
            .map(|i| {
                VEntry::put(1, i + 1, format!("key{:04}", i * 7 % 300), vec![(i % 251) as u8; 100])
            })
            .collect();
        let epoch1: Vec<VEntry> = (0..150u64)
            .map(|i| {
                if i % 11 == 3 {
                    VEntry::delete(1, 301 + i, format!("key{:04}", i * 2))
                } else {
                    VEntry::put(1, 301 + i, format!("key{:04}", i * 2), vec![3u8; 100])
                }
            })
            .collect();
        let cycle2 = |dir: &Path| -> GcInputs {
            let v1 = write_epoch_file(dir, 1, &epoch1);
            let mut inp = inputs(dir, v1, vec![vec![1]], 2, 450);
            inp.min_index = 300;
            inp.level0_bytes = 1; // force the L0 -> L1 merge
            inp.fanout = 1 << 20;
            inp.partition_bytes = 8 << 10; // ~40 KiB of sources -> >1 part
            inp.workers = 2;
            inp
        };
        let ref_dir = tmpdir("pmerge-ref");
        let v0 = write_epoch_file(&ref_dir, 0, &epoch0);
        run_gc(&inputs(&ref_dir, v0, vec![], 1, 300)).unwrap();
        let ref_out = run_gc(&cycle2(&ref_dir)).unwrap();
        assert_eq!(ref_out.merges, 1);
        assert!(ref_out.parts >= 2, "plan produced {} partitions", ref_out.parts);
        assert_eq!(ref_out.partitions.len(), 1, "{:?}", ref_out.partitions);
        let part_gens = ref_out.partitions[0].gens.clone();
        assert_eq!(part_gens.len() as u64, ref_out.parts);

        let dir = tmpdir("pmerge-crash");
        let v0 = write_epoch_file(&dir, 0, &epoch0);
        run_gc(&inputs(&dir, v0, vec![], 1, 300)).unwrap();
        let mut inp = cycle2(&dir);
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.levels, ref_out.levels);
        assert_eq!(out.partitions, ref_out.partitions);
        // Tear the SECOND partition's output mid-frame; its sealed
        // siblings stay intact, as after a mid-merge crash.
        let victim = part_gens[1];
        let full = std::fs::read(sorted_path(&dir, victim)).unwrap();
        assert_eq!(full, std::fs::read(sorted_path(&ref_dir, victim)).unwrap());
        std::fs::write(sorted_path(&dir, victim), &full[..full.len() * 2 / 3]).unwrap();
        let _ = std::fs::remove_file(index_path(&dir, victim));
        inp.resume = true;
        let out = run_gc(&inp).unwrap();
        assert_eq!(out.levels, ref_out.levels);
        assert_eq!(out.partitions, ref_out.partitions);
        for &pg in &part_gens {
            assert_eq!(
                std::fs::read(sorted_path(&dir, pg)).unwrap(),
                std::fs::read(sorted_path(&ref_dir, pg)).unwrap(),
                "partition gen {pg} diverged after resume"
            );
        }
        // And the resumed stack answers lookups like the reference.
        let a = LeveledStorage::open_partitioned(&dir, &out.levels, &out.partitions).unwrap();
        let b = LeveledStorage::open_partitioned(&ref_dir, &ref_out.levels, &ref_out.partitions)
            .unwrap();
        for i in (0..300u64).step_by(13) {
            let k = format!("key{i:04}");
            assert_eq!(
                a.get(k.as_bytes()).unwrap().map(|e| e.value),
                b.get(k.as_bytes()).unwrap().map(|e| e.value),
                "{k}"
            );
        }
    }
}
