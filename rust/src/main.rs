//! `nezha` CLI — launcher for the reproduction.
//!
//! ```text
//! nezha serve   --node 1 --peers 1=127.0.0.1:7100,2=127.0.0.1:7200,3=127.0.0.1:7300 \
//!               [--shards S] [--engine E] [--dir PATH] [--read-from WHO]
//! nezha client  --peers 1=...,2=...,3=... [--shards S] put KEY VALUE
//! nezha client  --peers ... get KEY | del KEY | scan START END LIMIT | status
//! nezha load    --engine nezha --records 10000 --value-size 16384
//! nezha ycsb    --engine nezha --workload A --ops 2000
//! nezha recover --dir <replica base dir> --engine nezha
//! nezha chaos   --seed 7 [--schedule all] [--read-from leader] [--ms 4000] [--tcp]
//! nezha engines                      # list engine variants
//! ```
//!
//! `serve` runs **one process = one node**: this node's replica of
//! every shard group, Raft over real TCP (the `--peers` list names
//! each node's client address; shard `s`'s raft listener binds
//! `client_port + 1 + s`).  `client` is the thin framed-TCP client.
//! `load`/`ycsb` spin up a full in-process cluster instead (the bench
//! harness path).  Arg parsing is hand-rolled (clap is unavailable
//! offline — DESIGN.md §2).

use anyhow::{anyhow, bail, Context, Result};
use nezha::coordinator::{Client, ClusterConfig, Server, ServerOpts, ShardRouter, StatusRow};
use nezha::engine::EngineKind;
use nezha::harness::{parse_read_from_arg, print_header, Env, Spec};
use nezha::raft::NodeId;
use nezha::ycsb::WorkloadKind;
use std::collections::{BTreeMap, HashMap};
use std::net::{SocketAddr, ToSocketAddrs};
use std::time::Duration;

fn usage() -> ! {
    eprintln!(
        "nezha — key-value separated distributed store (paper reproduction)

USAGE:
  nezha serve   --node N --peers LIST [--shards S] [--engine E] [--dir PATH] [--read-from WHO]
                [--learner]
  nezha client  --peers LIST [--shards S] put KEY VALUE | get KEY | del KEY |
                scan START END LIMIT | status |
                add-node NODE [SHARD] | remove-node NODE [SHARD]
  nezha load    [--engine E] [--nodes N] [--shards S] [--records R] [--value-size B]
  nezha ycsb    [--engine E] [--workload A..F] [--shards S] [--ops N] [--records R] [--value-size B]
  nezha recover --dir PATH [--engine E]
  nezha chaos   [--seed N] [--schedule NAME|all] [--read-from WHO] [--clients C]
                [--ms MS] [--tcp]
  nezha engines

PEERS is `id=host:port,...` naming every node's client address; node N's raft
listener for shard S binds the same host at port+1+S.  WHO is
leader|followers|stale.

`serve --learner` starts the node as a non-voting learner — the join flow is
`client add-node N` at the running cluster, then `serve --learner` for node N
with the extended peer list; the leader streams it a snapshot, promotes it to
voter once caught up, and the flag is ignored on later restarts (the persisted
membership wins).  `client remove-node N` shrinks the group; removing the
current leader transfers leadership after the change commits.

`chaos` runs a seeded nemesis schedule (partitions, link flapping, disk-fault +
crash + restart) against a live in-process cluster while concurrent clients
record a history, then checks it for linearizability.  Exits non-zero on any
violation.  Schedules: partition-heal, crash-restart-mid-gc, flapping-links,
torn-group-commit, torn-partitioned-merge, torn-snapshot-stream,
membership-churn.

ENGINES: {}",
        EngineKind::ALL.map(|k| k.name()).join(", ")
    );
    std::process::exit(2)
}

/// Split argv into `--flag value` (or `--flag=value`) pairs plus the
/// remaining positional words, in order.
fn parse_args(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut pos = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else {
                let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    i += 1;
                    args[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            }
        } else {
            pos.push(args[i].clone());
        }
        i += 1;
    }
    (flags, pos)
}

fn flag<T: std::str::FromStr>(m: &HashMap<String, String>, k: &str, default: T) -> T {
    m.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn engine_of(m: &HashMap<String, String>) -> Result<EngineKind> {
    let name = m.get("engine").map(String::as_str).unwrap_or("nezha");
    EngineKind::parse(name).with_context(|| format!("unknown engine {name:?}"))
}

/// Parse `1=host:port,2=host:port,...` into the node→address map.
fn parse_peers(s: &str) -> Result<BTreeMap<NodeId, SocketAddr>> {
    let mut m = BTreeMap::new();
    for part in s.split(',') {
        let (id, addr) = part
            .split_once('=')
            .with_context(|| format!("peer {part:?} is not id=host:port"))?;
        let id: NodeId = id.trim().parse().with_context(|| format!("bad node id {id:?}"))?;
        let addr = addr
            .trim()
            .to_socket_addrs()
            .with_context(|| format!("bad address {addr:?}"))?
            .next()
            .ok_or_else(|| anyhow!("address {addr:?} resolved to nothing"))?;
        m.insert(id, addr);
    }
    if m.is_empty() {
        bail!("--peers list is empty");
    }
    Ok(m)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (flags, pos) = parse_args(&args[1..]);
    match cmd.as_str() {
        "engines" => {
            for k in EngineKind::ALL {
                println!("{}", k.name());
            }
            Ok(())
        }
        "serve" => cmd_serve(&flags),
        "client" => cmd_client(&flags, &pos),
        "load" => cmd_load(&flags),
        "ycsb" => cmd_ycsb(&flags),
        "recover" => cmd_recover(&flags),
        "chaos" => cmd_chaos(&flags),
        _ => usage(),
    }
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let kind = engine_of(flags)?;
    let peers = parse_peers(flags.get("peers").context("--peers required")?)?;
    let node: NodeId = flag(flags, "node", 0);
    if node == 0 {
        bail!("--node N required (one of the ids in --peers)");
    }
    let shards: u32 = flag(flags, "shards", 1);
    let dir = flags.get("dir").cloned().unwrap_or_else(|| format!("./nezha-node-{node}"));
    let mut cfg = ClusterConfig::new(dir.clone(), kind, peers.len());
    cfg.router = ShardRouter::hash(shards.max(1));
    if let Some(rf) = flags.get("read-from") {
        cfg.read_consistency = parse_read_from_arg(&["--read-from".to_string(), rf.clone()])
            .with_context(|| format!("bad --read-from {rf:?} (leader|followers|stale)"))?;
    }
    let learner = flags.contains_key("learner");
    let server = Server::start(ServerOpts { node, peers, cluster: cfg, learner })?;
    if learner {
        println!("node {node} joining as a non-voting learner (promotion is automatic)");
    }
    println!(
        "node {node} up: engine {}, {} shard group(s), data under {dir}",
        kind.name(),
        shards.max(1)
    );
    println!(
        "clients at {}; raft listeners at ports +1..+{} — ctrl-c to stop",
        server.client_addr(),
        shards.max(1)
    );
    // Park forever, logging a status heartbeat.  An abrupt kill is a
    // supported fault: peers count the dead connections as dropped and
    // re-elect, and restart recovers from the data dir.
    loop {
        std::thread::sleep(Duration::from_secs(30));
        let wire = server.wire_stats();
        let rows: Vec<String> = server
            .status()
            .iter()
            .map(|r| format!("s{}:{}@t{} a{}", r.shard, r.role, r.term, r.last_applied))
            .collect();
        println!(
            "status: {} | wire: {} msgs, {:.1} MiB ({:.1} MiB snap), {} dropped",
            rows.join(" "),
            wire.msgs,
            wire.bytes as f64 / (1 << 20) as f64,
            wire.snap_bytes as f64 / (1 << 20) as f64,
            wire.dropped
        );
    }
}

fn print_status_rows(node: NodeId, rows: &[StatusRow]) {
    for r in rows {
        println!(
            "node {node} shard {}: {:<9} term {:<4} applied {:<8} leader_hint {}",
            r.shard,
            r.role,
            r.term,
            r.last_applied,
            r.leader_hint.map_or_else(|| "-".into(), |h| h.to_string())
        );
    }
}

fn cmd_client(flags: &HashMap<String, String>, pos: &[String]) -> Result<()> {
    let peers = parse_peers(flags.get("peers").context("--peers required")?)?;
    let shards: u32 = flag(flags, "shards", 1);
    let nodes: Vec<NodeId> = peers.keys().copied().collect();
    let mut client = Client::connect(peers, shards.max(1));
    let op = pos.first().map(String::as_str).unwrap_or("");
    match op {
        "put" => {
            let k = pos.get(1).context("put KEY VALUE")?;
            let v = pos.get(2).context("put KEY VALUE")?;
            client.put(k.as_bytes(), v.as_bytes())?;
            println!("OK");
        }
        "get" => {
            let k = pos.get(1).context("get KEY")?;
            match client.get(k.as_bytes())? {
                Some(v) => println!("{} ({} bytes)", String::from_utf8_lossy(&v), v.len()),
                None => println!("(nil)"),
            }
        }
        "del" => {
            let k = pos.get(1).context("del KEY")?;
            client.delete(k.as_bytes())?;
            println!("OK");
        }
        "scan" => {
            let start = pos.get(1).context("scan START END LIMIT")?;
            let end = pos.get(2).context("scan START END LIMIT")?;
            let limit: usize = pos.get(3).context("scan START END LIMIT")?.parse()?;
            let rows = client.scan(start.as_bytes(), end.as_bytes(), limit)?;
            for (k, v) in &rows {
                println!("{} = {} bytes", String::from_utf8_lossy(k), v.len());
            }
            println!("({} rows)", rows.len());
        }
        "status" => {
            for node in nodes {
                match client.status(node) {
                    Ok(rows) => print_status_rows(node, &rows),
                    Err(e) => println!("node {node}: unreachable ({e:#})"),
                }
            }
        }
        "add-node" => {
            let n: NodeId = pos.get(1).context("add-node NODE [SHARD]")?.parse()?;
            let shard: u32 = pos.get(2).map_or(Ok(0), |s| s.parse())?;
            client.add_node(shard, n)?;
            println!("OK: node {n} added to shard {shard} as a learner; start it with `nezha serve --node {n} --learner` and the extended --peers list");
        }
        "remove-node" => {
            let n: NodeId = pos.get(1).context("remove-node NODE [SHARD]")?.parse()?;
            let shard: u32 = pos.get(2).map_or(Ok(0), |s| s.parse())?;
            client.remove_node(shard, n)?;
            println!("OK: node {n} removed from shard {shard}; its process can be stopped");
        }
        _ => bail!("client op must be put|get|del|scan|status|add-node|remove-node"),
    }
    Ok(())
}

fn cmd_load(flags: &HashMap<String, String>) -> Result<()> {
    let kind = engine_of(flags)?;
    let nodes: usize = flag(flags, "nodes", 3);
    let value_size: usize = flag(flags, "value-size", 16 << 10);
    let records: u64 = flag(flags, "records", 2048);

    let mut spec = Spec::new(kind, value_size);
    spec.nodes = nodes;
    spec.shards = flag(flags, "shards", 1);
    spec.load_bytes = records * value_size as u64;
    println!(
        "starting {} cluster: {} nodes x {} shard group(s), {} records x {} B",
        kind.name(),
        nodes,
        spec.shards,
        records,
        value_size
    );
    let env = Env::start(spec)?;
    let m = env.load("load")?;
    print_header("load");
    println!("{}", m.row());
    env.destroy()
}

fn cmd_ycsb(flags: &HashMap<String, String>) -> Result<()> {
    let kind = engine_of(flags)?;
    let wl = flags.get("workload").map(String::as_str).unwrap_or("A");
    let Some(wl) = WorkloadKind::parse(wl) else {
        bail!("unknown workload {wl:?}");
    };
    let ops: u64 = flag(flags, "ops", 2_000);
    let value_size: usize = flag(flags, "value-size", 16 << 10);
    let records: u64 = flag(flags, "records", 1024);

    let mut spec = Spec::new(kind, value_size);
    spec.nodes = flag(flags, "nodes", 3);
    spec.shards = flag(flags, "shards", 1);
    spec.load_bytes = records * value_size as u64;
    let env = Env::start(spec)?;
    env.load("preload")?;
    env.settle()?;
    let (m, wlat, rlat) = env.run_ycsb(wl, ops, 100)?;
    print_header(&format!("YCSB-{}", wl.name()));
    println!("{}", m.row());
    println!("write lat: {}", wlat.summary());
    println!("read  lat: {}", rlat.summary());
    env.destroy()
}

fn cmd_chaos(flags: &HashMap<String, String>) -> Result<()> {
    use nezha::chaos::{run_chaos, ChaosOpts, ScheduleKind};
    let seed: u64 = flag(flags, "seed", 7);
    let schedules: Vec<ScheduleKind> = match flags.get("schedule").map(String::as_str) {
        None | Some("all") => ScheduleKind::ALL.to_vec(),
        Some(name) => vec![ScheduleKind::parse(name).with_context(|| {
            format!(
                "unknown schedule {name:?} (have: {})",
                ScheduleKind::ALL.map(|k| k.name()).join(", ")
            )
        })?],
    };
    let mut failed = false;
    for schedule in schedules {
        let mut opts = ChaosOpts::new(seed, schedule);
        if let Some(rf) = flags.get("read-from") {
            opts.read_consistency = parse_read_from_arg(&["--read-from".to_string(), rf.clone()])
                .with_context(|| format!("bad --read-from {rf:?} (leader|followers|stale)"))?;
        }
        opts.clients = flag(flags, "clients", 3);
        opts.run_ms = flag(flags, "ms", 4_000);
        if flags.contains_key("tcp") {
            opts.transport = nezha::raft::TransportKind::Tcp;
        }
        println!(
            "chaos seed {seed} schedule {} ({:?}, {} clients, {} ms)...",
            schedule.name(),
            opts.read_consistency,
            opts.clients,
            opts.run_ms
        );
        let report = run_chaos(&opts)?;
        for line in &report.nemesis_log {
            println!("  nemesis {line}");
        }
        println!(
            "  {} writes ({} indeterminate), {} reads, {} restarted",
            report.writes,
            report.indeterminate,
            report.reads,
            report.restarted.len()
        );
        match &report.violation {
            None => println!("  OK: history is {:?}-consistent", opts.read_consistency),
            Some(v) => {
                println!("  VIOLATION: {v}");
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_recover(flags: &HashMap<String, String>) -> Result<()> {
    // Recovery drill: reopen a replica directory and report how long
    // state reconstruction takes (Figure 11's measurement).
    let kind = engine_of(flags)?;
    let dir = flags.get("dir").context("--dir required")?;
    let base = std::path::PathBuf::from(dir);
    let t0 = std::time::Instant::now();
    let replica = nezha::coordinator::Replica::open(
        1,
        vec![],
        &base,
        kind,
        nezha::engine::EngineOpts::new("unset", "unset"),
        nezha::raft::Config::default(),
        nezha::gc::GcConfig::default(),
        7,
    )?;
    let wall = t0.elapsed();
    println!(
        "recovered {} replica at {dir}: last_index={} gc_phase={:?} in {:.1} ms",
        kind.name(),
        replica.node.log.last_index(),
        replica.engine().gc_phase(),
        wall.as_secs_f64() * 1e3
    );
    // Sanity read.
    let _ = replica.engine().scan(b"", &[0xff; 16], 1)?;
    Ok(())
}
