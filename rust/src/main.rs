//! `nezha` CLI — launcher for the reproduction.
//!
//! ```text
//! nezha serve   --engine nezha --nodes 3 --dir /tmp/nezha [--ops N]
//! nezha load    --engine nezha --records 10000 --value-size 16384
//! nezha ycsb    --engine nezha --workload A --ops 2000
//! nezha recover --dir <replica base dir> --engine nezha
//! nezha engines                      # list engine variants
//! ```
//!
//! Arg parsing is hand-rolled (clap is unavailable offline —
//! DESIGN.md §2).

use anyhow::{bail, Context, Result};
use nezha::engine::EngineKind;
use nezha::harness::{print_header, Env, Spec};
use nezha::ycsb::WorkloadKind;
use std::collections::HashMap;

fn usage() -> ! {
    eprintln!(
        "nezha — key-value separated distributed store (paper reproduction)

USAGE:
  nezha serve   [--engine E] [--nodes N] [--shards S] [--dir PATH] [--records R] [--value-size B]
  nezha load    [--engine E] [--nodes N] [--shards S] [--records R] [--value-size B]
  nezha ycsb    [--engine E] [--workload A..F] [--shards S] [--ops N] [--records R] [--value-size B]
  nezha recover --dir PATH [--engine E]
  nezha engines

ENGINES: {}",
        EngineKind::ALL.map(|k| k.name()).join(", ")
    );
    std::process::exit(2)
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            m.insert(name.to_string(), val);
        }
        i += 1;
    }
    m
}

fn flag<T: std::str::FromStr>(m: &HashMap<String, String>, k: &str, default: T) -> T {
    m.get(k).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn engine_of(m: &HashMap<String, String>) -> Result<EngineKind> {
    let name = m.get("engine").map(String::as_str).unwrap_or("nezha");
    EngineKind::parse(name).with_context(|| format!("unknown engine {name:?}"))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "engines" => {
            for k in EngineKind::ALL {
                println!("{}", k.name());
            }
            Ok(())
        }
        "load" | "serve" => cmd_load_serve(cmd == "serve", &flags),
        "ycsb" => cmd_ycsb(&flags),
        "recover" => cmd_recover(&flags),
        _ => usage(),
    }
}

fn cmd_load_serve(serve: bool, flags: &HashMap<String, String>) -> Result<()> {
    let kind = engine_of(flags)?;
    let nodes: usize = flag(flags, "nodes", 3);
    let value_size: usize = flag(flags, "value-size", 16 << 10);
    let records: u64 = flag(flags, "records", 2048);

    let mut spec = Spec::new(kind, value_size);
    spec.nodes = nodes;
    spec.shards = flag(flags, "shards", 1);
    spec.load_bytes = records * value_size as u64;
    println!(
        "starting {} cluster: {} nodes x {} shard group(s), {} records x {} B",
        kind.name(),
        nodes,
        spec.shards,
        records,
        value_size
    );
    let env = Env::start(spec)?;
    let m = env.load("load")?;
    print_header("load");
    println!("{}", m.row());
    if serve {
        println!(
            "cluster up; issuing a smoke get/scan then exiting (interactive serving is \
             exercised by examples/)"
        );
        let v = env.cluster.get(&nezha::ycsb::key_of(0))?;
        println!("get(user0) -> {} bytes", v.map_or(0, |v| v.len()));
        let rows =
            env.cluster.scan(&nezha::ycsb::key_of(0), &nezha::ycsb::key_of(u64::MAX / 2), 10)?;
        println!("scan(10) -> {} rows", rows.len());
    }
    env.destroy()
}

fn cmd_ycsb(flags: &HashMap<String, String>) -> Result<()> {
    let kind = engine_of(flags)?;
    let wl = flags
        .get("workload")
        .map(String::as_str)
        .unwrap_or("A");
    let Some(wl) = WorkloadKind::parse(wl) else {
        bail!("unknown workload {wl:?}");
    };
    let ops: u64 = flag(flags, "ops", 2_000);
    let value_size: usize = flag(flags, "value-size", 16 << 10);
    let records: u64 = flag(flags, "records", 1024);

    let mut spec = Spec::new(kind, value_size);
    spec.nodes = flag(flags, "nodes", 3);
    spec.shards = flag(flags, "shards", 1);
    spec.load_bytes = records * value_size as u64;
    let env = Env::start(spec)?;
    env.load("preload")?;
    env.settle()?;
    let (m, wlat, rlat) = env.run_ycsb(wl, ops, 100)?;
    print_header(&format!("YCSB-{}", wl.name()));
    println!("{}", m.row());
    println!("write lat: {}", wlat.summary());
    println!("read  lat: {}", rlat.summary());
    env.destroy()
}

fn cmd_recover(flags: &HashMap<String, String>) -> Result<()> {
    // Recovery drill: reopen a replica directory and report how long
    // state reconstruction takes (Figure 11's measurement).
    let kind = engine_of(flags)?;
    let dir = flags.get("dir").context("--dir required")?;
    let base = std::path::PathBuf::from(dir);
    let t0 = std::time::Instant::now();
    let mut replica = nezha::coordinator::Replica::open(
        1,
        vec![],
        &base,
        kind,
        nezha::engine::EngineOpts::new("unset", "unset"),
        nezha::raft::Config::default(),
        nezha::gc::GcConfig::default(),
        7,
    )?;
    let wall = t0.elapsed();
    println!(
        "recovered {} replica at {dir}: last_index={} gc_phase={:?} in {:.1} ms",
        kind.name(),
        replica.node.log.last_index(),
        replica.engine_ref().gc_phase(),
        wall.as_secs_f64() * 1e3
    );
    // Sanity read.
    let _ = replica.engine().scan(b"", &[0xff; 16], 1)?;
    Ok(())
}
