//! # Nezha — a key-value separated distributed store with optimized
//! # Raft integration (paper reproduction)
//!
//! This crate reproduces the system from *"Nezha: A Key-Value Separated
//! Distributed Store with Optimized Raft Integration"* (CS.DC 2026):
//!
//! * [`raft`] — a from-scratch Raft implementation whose log entries can
//!   carry full key-value payloads (the **KVS-Raft** substrate).
//! * [`lsm`] — a from-scratch LSM-tree storage engine (the RocksDB
//!   substitute): memtable, WAL, SSTables, leveled compaction.
//! * [`vlog`] — the ValueLog: append-only entry log addressed by offset,
//!   the sorted ValueLog produced by GC, and the file-backed hash index.
//! * [`gc`] — the Raft-aware garbage-collection framework with the
//!   Active / New / Final-Compacted storage modules and the three-phase
//!   (Pre/During/Post-GC) request processing of paper §III-C/D.
//! * [`engine`] — the seven evaluation configurations (Original, PASV,
//!   TiKV, Dwisckey, LSM-Raft, Nezha-NoGC, Nezha) behind one trait.
//! * [`coordinator`] — multi-node cluster runtime: shard routing,
//!   leader routing, group-commit batching, follower reads, metrics —
//!   plus the multi-process `nezha serve` server and its thin TCP
//!   client ([`coordinator::server`]).
//! * [`runtime`] — PJRT loader for the AOT-compiled JAX/Pallas
//!   index-build module (`artifacts/index_build.hlo.txt`), plus the
//!   event-driven replica reactor ([`runtime::reactor`]) that
//!   multiplexes every (shard, node) loop of a process over a small
//!   worker pool.
//! * [`ycsb`] — YCSB workload generator (Load, A–F).
//! * [`harness`] — the experiment harness regenerating every paper
//!   figure (see `benches/fig*.rs`).
//! * [`fault`] — deterministic fault injection: the runtime-mutable
//!   network [`fault::FaultPlan`] shared by every transport, and the
//!   [`fault::disk`] registry failing the Nth fsync/write on armed
//!   storage paths.
//! * [`check`] — WGL-style linearizability checker over recorded
//!   per-client register histories.
//! * [`chaos`] — the nemesis harness: concurrent clients + fault
//!   schedules against a live cluster, verified by [`check`]
//!   (`rust/tests/chaos.rs`, `nezha chaos --seed N`).
//!
//! The cluster runs over one of two interchangeable transports
//! ([`raft::transport`]): the in-process bus the early reproduction
//! measured with, or real TCP sockets — in one process over loopback
//! (`--transport tcp`) or across processes (`nezha serve`).
//!
//! See `README.md` for the quickstart, `DESIGN.md` §1–§8 for the
//! paper→repo mapping, substitutions and subsystem contracts, and
//! `ROADMAP.md` for invariants and open items.

pub mod util;
pub mod lsm;
pub mod vlog;
pub mod raft;
pub mod engine;
pub mod gc;
pub mod coordinator;
pub mod runtime;
pub mod ycsb;
pub mod harness;
pub mod fault;
pub mod check;
pub mod chaos;

pub use engine::{EngineKind, KvEngine};

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
