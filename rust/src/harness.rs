//! Experiment harness: spins up clusters, loads data, drives
//! operation mixes, and prints the paper-style rows the `benches/fig*`
//! binaries emit.  Workloads are scaled from the paper's testbed
//! (100 GB loads on a 3-node SSD cluster) to laptop scale; the
//! *shapes* — who wins and by roughly what factor — are the
//! reproduction target (DESIGN.md §4).

use crate::coordinator::{Cluster, ClusterConfig, ReadConsistency, ShardRouter};
use crate::engine::EngineKind;
use crate::gc::GcConfig;
use crate::raft::{NetConfig, TransportKind};
use crate::util::Histogram;
use crate::ycsb::{key_of, Generator, Op, WorkloadKind};
use anyhow::Result;
use std::path::PathBuf;
use std::time::Instant;

/// Scale factor: 1.0 = default bench scale (NEZHA_BENCH_SCALE env).
pub fn bench_scale() -> f64 {
    std::env::var("NEZHA_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        // 0.5 keeps the full 9-figure suite under ~15 min on one core;
        // the paper-shape checks are stable from ~0.3 upward.
        .unwrap_or(0.5)
}

/// Parse a `--shards N` (or `--shards=N`) flag out of an argv slice.
pub fn parse_shards_arg(args: &[String]) -> Option<usize> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--shards" {
            return it.next().and_then(|v| v.parse().ok());
        }
        if let Some(v) = a.strip_prefix("--shards=") {
            return v.parse().ok();
        }
    }
    None
}

/// Shard count for benches: `--shards N` on the bench command line
/// (`cargo bench --bench fig5_get -- --shards 4`) or the
/// `NEZHA_BENCH_SHARDS` env var; defaults to 1 (the pre-sharding
/// layout).  The fig5/fig6/fig10 sweeps use this to plot shard
/// scaling curves on the same hardware.
pub fn bench_shards() -> usize {
    let args: Vec<String> = std::env::args().collect();
    parse_shards_arg(&args)
        .or_else(|| std::env::var("NEZHA_BENCH_SHARDS").ok().and_then(|s| s.parse().ok()))
        .unwrap_or(1)
        .max(1)
}

/// Parse a `--clients N` (or `--clients=N`) flag out of an argv slice.
pub fn parse_clients_arg(args: &[String]) -> Option<usize> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--clients" {
            return it.next().and_then(|v| v.parse().ok());
        }
        if let Some(v) = a.strip_prefix("--clients=") {
            return v.parse().ok();
        }
    }
    None
}

/// Concurrent client threads for the put experiment: `--clients N` on
/// the bench command line (`cargo bench --bench fig4_put -- --clients
/// 8`) or the `NEZHA_BENCH_CLIENTS` env var; defaults to 1 (the
/// original single-stream load).  Overlapping clients are what give
/// group commit batches to amortize — one lock-step stream commits
/// before the next proposal arrives.
pub fn bench_clients() -> usize {
    let args: Vec<String> = std::env::args().collect();
    parse_clients_arg(&args)
        .or_else(|| std::env::var("NEZHA_BENCH_CLIENTS").ok().and_then(|s| s.parse().ok()))
        .unwrap_or(1)
        .max(1)
}

/// Parse a `--gc-workers N` (or `--gc-workers=N`) flag out of an argv
/// slice.
pub fn parse_gc_workers_arg(args: &[String]) -> Option<usize> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--gc-workers" {
            return it.next().and_then(|v| v.parse().ok());
        }
        if let Some(v) = a.strip_prefix("--gc-workers=") {
            return v.parse().ok();
        }
    }
    None
}

/// Merge partitions in flight per level merge: `--gc-workers N` on the
/// bench command line (`cargo bench --bench fig10_gc_impact --
/// --gc-workers 4`) or the `NEZHA_BENCH_GC_WORKERS` env var; defaults
/// to 1 (serial merges — byte-identical output either way).  fig10
/// uses this to compare GC-overlap throughput at both settings.
pub fn bench_gc_workers() -> usize {
    let args: Vec<String> = std::env::args().collect();
    parse_gc_workers_arg(&args)
        .or_else(|| std::env::var("NEZHA_BENCH_GC_WORKERS").ok().and_then(|s| s.parse().ok()))
        .unwrap_or(1)
        .max(1)
}

/// Parse a `--read-from WHO` (or `--read-from=WHO`) flag: `leader`
/// (default; every read at the shard leader), `followers` (ReadIndex/
/// lease-barriered linearizable reads spread over all replicas), or
/// `stale` (replica-local reads, no barrier).
pub fn parse_read_from_arg(args: &[String]) -> Option<ReadConsistency> {
    let parse = |v: &str| match v.to_ascii_lowercase().as_str() {
        "leader" => Some(ReadConsistency::Leader),
        "followers" | "follower" | "linearizable" => Some(ReadConsistency::Linearizable),
        "stale" => Some(ReadConsistency::Stale),
        _ => None,
    };
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--read-from" {
            return it.next().and_then(|v| parse(v));
        }
        if let Some(v) = a.strip_prefix("--read-from=") {
            return parse(v);
        }
    }
    None
}

/// Read routing for benches: `--read-from leader|followers|stale` on
/// the bench command line or the `NEZHA_BENCH_READ_FROM` env var;
/// defaults to leader-served reads.  fig5/fig6/fig8 use this to plot
/// leader vs follower read scaling at the same shard count.
pub fn bench_read_from() -> ReadConsistency {
    let args: Vec<String> = std::env::args().collect();
    parse_read_from_arg(&args)
        .or_else(|| {
            std::env::var("NEZHA_BENCH_READ_FROM")
                .ok()
                .and_then(|v| parse_read_from_arg(&["--read-from".into(), v]))
        })
        .unwrap_or(ReadConsistency::Leader)
}

/// Short label for bench headers/rows.
pub fn read_from_label(rf: ReadConsistency) -> &'static str {
    match rf {
        ReadConsistency::Leader => "leader",
        ReadConsistency::Linearizable => "followers",
        ReadConsistency::Stale => "stale",
    }
}

/// Parse a `--transport KIND` (or `--transport=KIND`) flag: `inproc`
/// (default; the in-process bus) or `tcp` (real loopback sockets).
pub fn parse_transport_arg(args: &[String]) -> Option<TransportKind> {
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--transport" {
            return it.next().and_then(|v| TransportKind::parse(v));
        }
        if let Some(v) = a.strip_prefix("--transport=") {
            return TransportKind::parse(v);
        }
    }
    None
}

/// Raft transport for benches: `--transport inproc|tcp` on the bench
/// command line or the `NEZHA_BENCH_TRANSPORT` env var; defaults to
/// the in-process bus.  fig4/fig5 use this to report in-process vs
/// real-TCP deltas on the same workload (DESIGN.md §2).
pub fn bench_transport() -> TransportKind {
    let args: Vec<String> = std::env::args().collect();
    if let Some(t) = parse_transport_arg(&args) {
        return t;
    }
    std::env::var("NEZHA_BENCH_TRANSPORT")
        .ok()
        .and_then(|v| TransportKind::parse(&v))
        .unwrap_or_default()
}

/// Point reads folded into one leader round-trip (the read analogue of
/// the coordinator's write-side fold).
pub const GET_BATCH: usize = 16;

/// One experiment configuration.
#[derive(Clone, Debug)]
pub struct Spec {
    pub kind: EngineKind,
    pub nodes: usize,
    /// Independent consensus groups the keyspace is hash-partitioned
    /// across (1 = the pre-sharding single-group layout).
    pub shards: usize,
    pub value_size: usize,
    /// Bytes of user data to load.
    pub load_bytes: u64,
    /// GC threshold as a fraction of loaded bytes (paper: 40 GB of
    /// 100 GB = 0.4).
    pub gc_fraction: f64,
    /// Who serves reads (see [`ReadConsistency`]); `Leader` is the
    /// pre-follower-read behavior.
    pub read_from: ReadConsistency,
    /// Which wire carries Raft frames: the in-process bus (default)
    /// or real loopback TCP sockets.
    pub transport: TransportKind,
    /// Concurrent client threads driving the load phase (1 = the
    /// original single-stream load); see [`bench_clients`].
    pub clients: usize,
    /// Merge partitions in flight per GC level merge (1 = serial
    /// merges); see [`bench_gc_workers`].
    pub gc_workers: usize,
    pub seed: u64,
}

impl Spec {
    pub fn new(kind: EngineKind, value_size: usize) -> Self {
        Self {
            kind,
            nodes: 3,
            shards: 1,
            value_size,
            load_bytes: (24 << 20) as u64,
            gc_fraction: 0.4,
            read_from: ReadConsistency::Leader,
            transport: TransportKind::Inproc,
            clients: 1,
            gc_workers: 1,
            seed: 42,
        }
    }

    pub fn records(&self) -> u64 {
        (self.load_bytes / self.value_size as u64).max(16)
    }
}

/// Measured row for the tables.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub system: String,
    /// x-axis label (value size, workload name, cluster size, ...).
    pub x: String,
    pub ops: u64,
    pub wall_s: f64,
    /// Per-op latency samples in µs.  Ops issued through a batched
    /// call (`put_batch`, `get_batch`) are each recorded at the
    /// *batch mean*, so for those columns p50/p99 describe batch
    /// behavior, not individual-op tails; scans (one call per op)
    /// remain true per-op samples.
    pub lat: Histogram,
    /// Payload bytes moved by the measured ops.
    pub bytes: u64,
}

impl Measurement {
    pub fn ops_per_sec(&self) -> f64 {
        self.ops as f64 / self.wall_s.max(1e-9)
    }

    pub fn mib_per_sec(&self) -> f64 {
        self.bytes as f64 / (1 << 20) as f64 / self.wall_s.max(1e-9)
    }

    pub fn row(&self) -> String {
        format!(
            "{:<11} {:>9} {:>10.0} {:>9.2} {:>9.0} {:>9} {:>9}",
            self.system,
            self.x,
            self.ops_per_sec(),
            self.mib_per_sec(),
            self.lat.mean(),
            self.lat.p50(),
            self.lat.p99(),
        )
    }
}

/// Print the indented readahead-cache line under a bench row.  Engines
/// without a readahead cache (no value separation) never touch the
/// counters and get no line.
pub fn print_readahead_line(st: &crate::engine::EngineStats) {
    if st.readahead_hits + st.readahead_misses > 0 {
        println!(
            "            readahead: {} hits / {} misses ({:.1}% hit, {} reads, {} KiB segs)",
            st.readahead_hits,
            st.readahead_misses,
            st.readahead_hit_rate() * 100.0,
            st.vlog_reads,
            st.readahead_seg_bytes >> 10,
        );
    }
}

/// Per-cycle GC report (fig10): flush vs merge bytes and the level
/// shape after each event.  With decoupled merge scheduling the
/// history interleaves `flush` cycles (epoch reclaim) and background
/// `merge` jobs (each with its own commit point); `parts` is the
/// number of key-range partitions a merge produced (0 for flushes,
/// 1 for unpartitioned merges).
pub fn print_gc_cycles(hist: &[crate::gc::GcOutput]) {
    if hist.is_empty() {
        return;
    }
    println!(
        "            {:<5} {:<6} {:>11} {:>11} {:>11} {:>7} {:>6} {:>8} {:>12}",
        "cycle",
        "kind",
        "flush_MiB",
        "merge_MiB",
        "total_MiB",
        "merges",
        "parts",
        "wall_ms",
        "level_shape"
    );
    let mib = |b: u64| b as f64 / (1 << 20) as f64;
    for (i, c) in hist.iter().enumerate() {
        let shape: Vec<String> = c.levels.iter().map(|l| l.len().to_string()).collect();
        println!(
            "            {:<5} {:<6} {:>11.2} {:>11.2} {:>11.2} {:>7} {:>6} {:>8} {:>12}",
            i + 1,
            if c.is_merge_job { "merge" } else { "flush" },
            mib(c.flush_bytes),
            mib(c.merge_bytes),
            mib(c.bytes_written),
            c.merges,
            c.parts,
            c.wall_ms,
            shape.join("/")
        );
    }
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
    println!("(lat columns: batched put/get ops are recorded at the batch mean; scans are per-op)");
    println!(
        "{:<11} {:>9} {:>10} {:>9} {:>9} {:>9} {:>9}",
        "system", "x", "ops/s", "MiB/s", "mean_us", "p50_us", "p99_us"
    );
}

/// A running cluster + its scratch directory.
pub struct Env {
    pub cluster: Cluster,
    dir: PathBuf,
    pub spec: Spec,
}

impl Env {
    pub fn start(spec: Spec) -> Result<Self> {
        let shards = spec.shards.max(1);
        let dir = std::env::temp_dir().join(format!(
            "nezha-bench-{}-{}-{}s-{}",
            spec.kind.name().to_ascii_lowercase().replace('-', ""),
            spec.value_size,
            shards,
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ClusterConfig::new(&dir, spec.kind, spec.nodes);
        cfg.seed = spec.seed;
        cfg.router = ShardRouter::hash(shards as u32);
        cfg.read_consistency = spec.read_from;
        cfg.transport = spec.transport;
        cfg.net = NetConfig { latency_us: (0, 0), loss: 0.0, seed: spec.seed };
        // Group commit on for the bench path: proposals arriving
        // within a 200 µs window share one raft-log persist, so
        // overlapping clients amortize syncs (fig4 reports the
        // fsyncs-per-committed-entry ratio).
        cfg.raft.group_commit_us = 200;
        // Engine scale knobs proportional to the per-shard load (each
        // shard group sees roughly `load / shards` of the traffic).
        let shard_load = (spec.load_bytes / shards as u64).max(1);
        cfg.engine.memtable_bytes = ((shard_load / 16).clamp(256 << 10, 16 << 20)) as usize;
        cfg.engine.level_base_bytes = (shard_load / 2).clamp(2 << 20, 128 << 20);
        cfg.gc = GcConfig {
            threshold_bytes: ((shard_load as f64 * spec.gc_fraction) as u64).max(1 << 20),
            ..Default::default()
        };
        // Leveled GC: L0 holds about one cycle's flush, deeper levels
        // grow by the fanout — per-cycle rewrite volume stays bounded
        // by level budgets instead of the total dataset.
        cfg.engine.gc_level0_bytes = cfg.gc.threshold_bytes;
        cfg.engine.gc_fanout = 10;
        // Partitioned merges: split level merges into ~4 key ranges at
        // bench scale so `--gc-workers > 1` has partitions to overlap.
        cfg.engine.gc_workers = spec.gc_workers.max(1);
        cfg.engine.gc_partition_bytes = (cfg.gc.threshold_bytes / 4).max(64 << 10);
        let cluster = Cluster::start(cfg)?;
        Ok(Self { cluster, dir, spec })
    }

    /// Load `records()` sequential inserts; returns the put
    /// measurement (this IS the put experiment).  With `spec.clients
    /// > 1` the key range is split into contiguous slices driven by
    /// that many concurrent client threads, so the leader sees
    /// overlapping proposals for group commit to batch instead of one
    /// lock-step stream.
    pub fn load(&self, label: &str) -> Result<Measurement> {
        let records = self.spec.records();
        let clients = (self.spec.clients.max(1) as u64).min(records);
        let per = records / clients;
        let t0 = Instant::now();
        let mut lat = Histogram::new();
        let mut loaded = 0u64;
        if clients == 1 {
            (loaded, lat) = self.load_range(0, records)?;
        } else {
            let parts: Vec<Result<(u64, Histogram)>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let start = c * per;
                        let end = if c == clients - 1 { records } else { start + per };
                        s.spawn(move || self.load_range(start, end))
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
            });
            for part in parts {
                let (n, h) = part?;
                loaded += n;
                lat.merge(&h);
            }
        }
        Ok(Measurement {
            system: self.spec.kind.name().into(),
            x: label.into(),
            ops: loaded,
            wall_s: t0.elapsed().as_secs_f64(),
            lat,
            bytes: loaded * self.spec.value_size as u64,
        })
    }

    /// One client's slice of the load: records `[start, end)` in
    /// ~2 MiB batches (big enough that consensus rounds amortize,
    /// small enough that batch-mean latency samples stay meaningful).
    fn load_range(&self, start: u64, end: u64) -> Result<(u64, Histogram)> {
        let vs = self.spec.value_size;
        let batch = ((2 << 20) / vs.max(1)).clamp(1, 256) as u64;
        let mut g = Generator::new(WorkloadKind::Load, 1, vs, self.spec.seed);
        let mut lat = Histogram::new();
        let mut loaded = 0u64;
        let mut r = start;
        while r < end {
            let n = batch.min(end - r);
            let ops: Vec<(Vec<u8>, Vec<u8>)> =
                (r..r + n).map(|i| (key_of(i), g.value_for(i))).collect();
            let bt0 = Instant::now();
            self.cluster.put_batch(ops)?;
            let per_op = bt0.elapsed().as_micros() as u64 / n.max(1);
            for _ in 0..n {
                lat.record(per_op.max(1));
            }
            loaded += n;
            r += n;
        }
        Ok((loaded, lat))
    }

    /// Issue `n` Zipf point queries, `GET_BATCH` at a time through
    /// [`Cluster::get_batch`] (one replica-channel crossing and one
    /// batched engine resolution per chunk); latency is recorded
    /// per-op as the batch mean, like the write path does.
    pub fn run_gets(&self, n: u64, label: &str) -> Result<Measurement> {
        let (records, vs) = (self.spec.records(), self.spec.value_size);
        let mut g = Generator::new(WorkloadKind::C, records, vs, self.spec.seed + 1);
        let keys: Vec<Vec<u8>> = (0..n)
            .map(|_| {
                let Op::Read(key) = g.next_op() else { unreachable!() };
                key
            })
            .collect();
        let mut lat = Histogram::new();
        let mut bytes = 0u64;
        let t0 = Instant::now();
        for chunk in keys.chunks(GET_BATCH) {
            let bt0 = Instant::now();
            let vals = self.cluster.get_batch(chunk)?;
            let per_op = (bt0.elapsed().as_micros() as u64 / chunk.len() as u64).max(1);
            for v in vals {
                if let Some(v) = v {
                    bytes += v.len() as u64;
                }
                lat.record(per_op);
            }
        }
        Ok(Measurement {
            system: self.spec.kind.name().into(),
            x: label.into(),
            ops: n,
            wall_s: t0.elapsed().as_secs_f64(),
            lat,
            bytes,
        })
    }

    /// Issue `n` range scans of `scan_len` records each.
    pub fn run_scans(&self, n: u64, scan_len: usize, label: &str) -> Result<Measurement> {
        let (records, vs) = (self.spec.records(), self.spec.value_size);
        let mut g = Generator::new(WorkloadKind::C, records, vs, self.spec.seed + 2);
        let mut lat = Histogram::new();
        let mut bytes = 0u64;
        let mut rows = 0u64;
        let t0 = Instant::now();
        for _ in 0..n {
            let Op::Read(start) = g.next_op() else { unreachable!() };
            let end = key_of(u64::MAX / 2);
            let ot0 = Instant::now();
            let got = self.cluster.scan(&start, &end, scan_len)?;
            lat.record(ot0.elapsed().as_micros().max(1) as u64);
            rows += got.len() as u64;
            bytes += got.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum::<u64>();
        }
        Ok(Measurement {
            system: self.spec.kind.name().into(),
            x: label.into(),
            ops: rows.max(n),
            wall_s: t0.elapsed().as_secs_f64(),
            lat,
            bytes,
        })
    }

    /// Run a YCSB mix of `n` ops; returns (overall, write-lat, read-lat).
    ///
    /// Runs of consecutive point reads are combined into one
    /// [`Cluster::get_batch`] call (up to `GET_BATCH` keys), the read
    /// analogue of the write path's group-commit folding.  The buffer
    /// is flushed before any write or scan executes, so cross-op
    /// ordering is preserved and memory stays O(`GET_BATCH`).
    pub fn run_ycsb(
        &self,
        kind: WorkloadKind,
        n: u64,
        scan_len: usize,
    ) -> Result<(Measurement, Histogram, Histogram)> {
        /// Issue the buffered read run as one batch; per-op latency is
        /// the batch mean, like the write path records.
        fn flush_reads(
            cluster: &Cluster,
            read_buf: &mut Vec<Vec<u8>>,
            lat: &mut Histogram,
            rlat: &mut Histogram,
            bytes: &mut u64,
        ) -> Result<()> {
            if read_buf.is_empty() {
                return Ok(());
            }
            let keys = std::mem::take(read_buf);
            let ot0 = Instant::now();
            let vals = cluster.get_batch(&keys)?;
            let per_op = (ot0.elapsed().as_micros() as u64 / keys.len() as u64).max(1);
            for v in vals {
                if let Some(v) = v {
                    *bytes += v.len() as u64;
                }
                lat.record(per_op);
                rlat.record(per_op);
            }
            Ok(())
        }

        let (records, vs) = (self.spec.records(), self.spec.value_size);
        let mut g = Generator::new(kind, records, vs, self.spec.seed + 3).with_scan_len(scan_len);
        let mut lat = Histogram::new();
        let mut wlat = Histogram::new();
        let mut rlat = Histogram::new();
        let mut bytes = 0u64;
        let mut read_buf: Vec<Vec<u8>> = Vec::with_capacity(GET_BATCH);
        let t0 = Instant::now();
        for _ in 0..n {
            // Bind the op once: reads are buffered (and `continue`),
            // everything else falls through still owning `op`.
            let op = match g.next_op() {
                Op::Read(k) => {
                    read_buf.push(k);
                    if read_buf.len() >= GET_BATCH {
                        flush_reads(&self.cluster, &mut read_buf, &mut lat, &mut rlat, &mut bytes)?;
                    }
                    continue;
                }
                op => op,
            };
            // A non-read op ends the read run.
            flush_reads(&self.cluster, &mut read_buf, &mut lat, &mut rlat, &mut bytes)?;
            let ot0 = Instant::now();
            match op {
                Op::Read(_) => unreachable!("buffered above"),
                Op::Update(k, v) | Op::Insert(k, v) => {
                    bytes += v.len() as u64;
                    self.cluster.put_batch(vec![(k, v)])?;
                    let us = ot0.elapsed().as_micros().max(1) as u64;
                    lat.record(us);
                    wlat.record(us);
                }
                Op::Rmw(k, v) => {
                    let _old = self.cluster.get(&k)?;
                    bytes += v.len() as u64;
                    self.cluster.put_batch(vec![(k, v)])?;
                    let us = ot0.elapsed().as_micros().max(1) as u64;
                    lat.record(us);
                    wlat.record(us);
                }
                Op::Scan(start, len) => {
                    let got = self.cluster.scan(&start, &key_of(u64::MAX / 2), len)?;
                    bytes += got.iter().map(|(k, v)| (k.len() + v.len()) as u64).sum::<u64>();
                    let us = ot0.elapsed().as_micros().max(1) as u64;
                    lat.record(us);
                    rlat.record(us);
                }
            }
        }
        flush_reads(&self.cluster, &mut read_buf, &mut lat, &mut rlat, &mut bytes)?;
        let m = Measurement {
            system: self.spec.kind.name().into(),
            x: kind.name().into(),
            ops: n,
            wall_s: t0.elapsed().as_secs_f64(),
            lat,
            bytes,
        };
        Ok((m, wlat, rlat))
    }

    /// Let any pending GC finish on every node (so read benches
    /// measure the Post-GC layout, like the paper's "loaded 100 GB
    /// then query" setup, without follower GC threads competing for
    /// this box's single core).
    pub fn settle(&self) -> Result<()> {
        self.cluster
            .wait_converged(std::time::Duration::from_secs(60))?;
        self.cluster.drain_gc_all()
    }

    /// Leader engine stats (readahead hit rate etc.) for bench rows.
    pub fn leader_stats(&self) -> Result<crate::engine::EngineStats> {
        let leader = self.cluster.wait_for_leader(std::time::Duration::from_secs(10))?;
        Ok(self.cluster.status(leader)?.engine)
    }

    /// Cluster-wide engine stats: with replica-served reads the
    /// traffic lands on whichever node executed it, so the leader row
    /// alone under-counts — this rollup is the honest accounting for
    /// read bench lines.
    pub fn cluster_stats(&self) -> Result<crate::engine::EngineStats> {
        self.cluster.cluster_stats()
    }

    /// Print which nodes actually served the reads (`nN:<gets>g/<scans>s`).
    pub fn print_read_distribution(&self) -> Result<()> {
        let dist = self.cluster.read_distribution()?;
        let parts: Vec<String> = dist.iter().map(|(id, g, s)| format!("n{id}:{g}g/{s}s")).collect();
        println!("            reads by node: {}", parts.join(" "));
        Ok(())
    }

    /// Print the raft wire volume this env's cluster moved so far
    /// (msgs/bytes/dropped summed over every shard's transport) — the
    /// line that makes in-process vs TCP runs comparable.
    pub fn print_wire_line(&self) {
        let w = self.cluster.wire_stats();
        println!(
            "            wire[{}]: {} msgs, {:.2} MiB ({:.2} MiB snap), {} dropped",
            self.spec.transport.name(),
            w.msgs,
            w.bytes as f64 / (1 << 20) as f64,
            w.snap_bytes as f64 / (1 << 20) as f64,
            w.dropped
        );
    }

    pub fn destroy(self) -> Result<()> {
        self.cluster.shutdown()?;
        let _ = std::fs::remove_dir_all(&self.dir);
        Ok(())
    }
}

/// Default engine sets for the figures.
pub fn all_engines() -> Vec<EngineKind> {
    EngineKind::ALL.to_vec()
}

/// Honor `NEZHA_BENCH_ENGINES=Nezha,Original,...` to subset.
pub fn engines_from_env() -> Vec<EngineKind> {
    match std::env::var("NEZHA_BENCH_ENGINES") {
        Ok(s) => s
            .split(',')
            .filter_map(|p| EngineKind::parse(p.trim()))
            .collect(),
        Err(_) => all_engines(),
    }
}

/// Value-size sweep (paper: 1 KB → 256 KB), scaled by
/// `NEZHA_BENCH_SCALE`.
pub fn value_sizes() -> Vec<usize> {
    vec![1 << 10, 4 << 10, 16 << 10, 64 << 10, 256 << 10]
}

/// Pretty-print a ratio summary (e.g. the paper's "+460.2%").
pub fn improvement_pct(nezha: f64, baseline: f64) -> f64 {
    (nezha / baseline.max(1e-9) - 1.0) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_records_scale_with_value_size() {
        let mut s = Spec::new(EngineKind::Nezha, 1 << 10);
        s.load_bytes = 1 << 20;
        assert_eq!(s.records(), 1024);
        s.value_size = 256 << 10;
        assert_eq!(s.records(), 16); // floor kicks in
    }

    #[test]
    fn improvement_math() {
        assert!((improvement_pct(5.6, 1.0) - 460.0).abs() < 1.0);
        assert!((improvement_pct(1.125, 1.0) - 12.5).abs() < 0.01);
    }

    #[test]
    fn tiny_end_to_end_put_get_scan() {
        // Smoke: the full harness path on a minuscule load.
        let mut spec = Spec::new(EngineKind::Nezha, 1 << 10);
        spec.load_bytes = 64 << 10;
        let env = Env::start(spec).unwrap();
        let put = env.load("1KB").unwrap();
        assert_eq!(put.ops, 64);
        let get = env.run_gets(20, "1KB").unwrap();
        assert!(get.bytes > 0, "gets found data");
        let scan = env.run_scans(5, 8, "1KB").unwrap();
        assert!(scan.ops >= 5);
        env.destroy().unwrap();
    }

    #[test]
    fn tiny_end_to_end_with_two_shards() {
        // The same harness path over a 2-shard cluster: ops split,
        // fan out and merge without the workload noticing.
        let mut spec = Spec::new(EngineKind::Nezha, 1 << 10);
        spec.load_bytes = 64 << 10;
        spec.shards = 2;
        let env = Env::start(spec).unwrap();
        let put = env.load("1KB").unwrap();
        assert_eq!(put.ops, 64);
        let get = env.run_gets(20, "1KB").unwrap();
        assert!(get.bytes > 0, "gets found data across shards");
        let scan = env.run_scans(5, 8, "1KB").unwrap();
        assert!(scan.ops >= 5);
        env.destroy().unwrap();
    }

    #[test]
    fn shards_flag_parses() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(parse_shards_arg(&args(&["bench", "--shards", "4"])), Some(4));
        assert_eq!(parse_shards_arg(&args(&["--shards=2"])), Some(2));
        assert_eq!(parse_shards_arg(&args(&["--scale", "1"])), None);
        assert_eq!(parse_shards_arg(&args(&["--shards"])), None);
        assert_eq!(parse_shards_arg(&args(&["--shards", "x"])), None);
    }

    #[test]
    fn clients_flag_parses() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(parse_clients_arg(&args(&["bench", "--clients", "8"])), Some(8));
        assert_eq!(parse_clients_arg(&args(&["--clients=2"])), Some(2));
        assert_eq!(parse_clients_arg(&args(&["--shards", "4"])), None);
        assert_eq!(parse_clients_arg(&args(&["--clients"])), None);
        assert_eq!(parse_clients_arg(&args(&["--clients", "x"])), None);
    }

    #[test]
    fn tiny_end_to_end_with_concurrent_clients() {
        // Four client threads split the key range; every record still
        // lands exactly once and the loaded data reads back.
        let mut spec = Spec::new(EngineKind::Nezha, 1 << 10);
        spec.load_bytes = 64 << 10;
        spec.clients = 4;
        let env = Env::start(spec).unwrap();
        let put = env.load("1KB").unwrap();
        assert_eq!(put.ops, 64);
        let get = env.run_gets(20, "1KB").unwrap();
        assert!(get.bytes > 0, "gets found data after concurrent load");
        let st = env.leader_stats().unwrap();
        assert!(st.entries_committed > 0, "leader committed nothing: {st:?}");
        env.destroy().unwrap();
    }

    #[test]
    fn gc_workers_flag_parses() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(parse_gc_workers_arg(&args(&["bench", "--gc-workers", "4"])), Some(4));
        assert_eq!(parse_gc_workers_arg(&args(&["--gc-workers=2"])), Some(2));
        assert_eq!(parse_gc_workers_arg(&args(&["--clients", "4"])), None);
        assert_eq!(parse_gc_workers_arg(&args(&["--gc-workers"])), None);
        assert_eq!(parse_gc_workers_arg(&args(&["--gc-workers", "x"])), None);
    }

    #[test]
    fn read_from_flag_parses() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(
            parse_read_from_arg(&args(&["bench", "--read-from", "followers"])),
            Some(ReadConsistency::Linearizable)
        );
        assert_eq!(
            parse_read_from_arg(&args(&["--read-from=stale"])),
            Some(ReadConsistency::Stale)
        );
        assert_eq!(
            parse_read_from_arg(&args(&["--read-from", "Leader"])),
            Some(ReadConsistency::Leader)
        );
        assert_eq!(parse_read_from_arg(&args(&["--read-from", "nope"])), None);
        assert_eq!(parse_read_from_arg(&args(&["--read-from"])), None);
        assert_eq!(parse_read_from_arg(&args(&["--shards", "2"])), None);
    }

    #[test]
    fn transport_flag_parses() {
        let args = |v: &[&str]| v.iter().map(|s| s.to_string()).collect::<Vec<_>>();
        assert_eq!(
            parse_transport_arg(&args(&["bench", "--transport", "tcp"])),
            Some(TransportKind::Tcp)
        );
        assert_eq!(
            parse_transport_arg(&args(&["--transport=inproc"])),
            Some(TransportKind::Inproc)
        );
        assert_eq!(parse_transport_arg(&args(&["--transport", "carrier-pigeon"])), None);
        assert_eq!(parse_transport_arg(&args(&["--transport"])), None);
        assert_eq!(parse_transport_arg(&args(&["--shards", "2"])), None);
    }

    #[test]
    fn tiny_end_to_end_over_tcp() {
        // The harness path with every raft frame crossing real
        // loopback sockets.
        let mut spec = Spec::new(EngineKind::Nezha, 1 << 10);
        spec.load_bytes = 64 << 10;
        spec.transport = TransportKind::Tcp;
        let env = Env::start(spec).unwrap();
        let put = env.load("1KB").unwrap();
        assert_eq!(put.ops, 64);
        let get = env.run_gets(20, "1KB").unwrap();
        assert!(get.bytes > 0, "gets found data over tcp");
        let w = env.cluster.wire_stats();
        assert!(w.msgs > 0 && w.bytes > 0, "no frames crossed the sockets: {w:?}");
        env.destroy().unwrap();
    }

    #[test]
    fn tiny_end_to_end_follower_reads() {
        // The harness path with reads spread over all replicas behind
        // ReadIndex barriers.
        let mut spec = Spec::new(EngineKind::Nezha, 1 << 10);
        spec.load_bytes = 64 << 10;
        spec.read_from = ReadConsistency::Linearizable;
        let env = Env::start(spec).unwrap();
        env.load("1KB").unwrap();
        let get = env.run_gets(30, "1KB").unwrap();
        assert!(get.bytes > 0, "follower gets found data");
        let scan = env.run_scans(4, 8, "1KB").unwrap();
        assert!(scan.ops >= 4);
        // More than one node served gets.
        let dist = env.cluster.read_distribution().unwrap();
        assert!(dist.iter().filter(|(_, g, _)| *g > 0).count() >= 2, "{dist:?}");
        env.destroy().unwrap();
    }
}
