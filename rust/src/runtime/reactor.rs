//! Event-driven replica runtime (DESIGN.md §6): a small worker pool
//! multiplexing many **non-blocking** tasks, driven by explicit wakes
//! (mailbox doorbells, client-request doorbells, apply-lane
//! completions) and a timer wheel for tick deadlines.
//!
//! This replaces the one-OS-thread-per-(shard, node) loops the
//! coordinator used to spawn: a 64-shard, 3-node in-process cluster is
//! 192 replicas, which as blocking threads each burn a 300µs mailbox
//! poll — as reactor tasks they share a handful of workers and run
//! only when something actually happened.
//!
//! Contract: [`Task::poll`] must never block.  It drains whatever
//! input is ready, does one bounded slice of work, and returns
//! [`PollOutcome::Pending`] (sleep until woken, optionally with a
//! deadline), [`PollOutcome::Yield`] (more work ready now — requeue
//! behind other runnable tasks), or [`PollOutcome::Done`] (drop the
//! task).  Wakes are coalescing and never lost: a wake that lands
//! while the task is mid-poll marks it dirty, and the worker requeues
//! it instead of parking it.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Opaque task handle returned by [`Reactor::spawn`].
pub type TaskId = u64;

/// What a task's [`Task::poll`] tells the worker to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollOutcome {
    /// Nothing more to do until woken.  With `Some(at)`, the reactor
    /// also wakes the task at `at` (tick/batch deadlines); spurious or
    /// stale timer wakes are allowed — polls must be idempotent.
    Pending(Option<Instant>),
    /// More work is immediately available: requeue this task behind
    /// other runnable tasks instead of hogging the worker.
    Yield,
    /// The task is finished; the reactor drops it.
    Done,
}

/// A non-blocking unit of execution (one replica's consensus loop, one
/// apply lane, ...).
pub trait Task: Send {
    fn poll(&mut self) -> PollOutcome;
}

/// Lifecycle used to coalesce wakes: `Idle` (parked), `Queued` (in the
/// run queue), `Running` (a worker is mid-poll), `RunningDirty` (woken
/// mid-poll — requeue on return instead of parking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TaskState {
    Idle,
    Queued,
    Running,
    RunningDirty,
}

struct Slot {
    /// Taken by the polling worker, restored on park; `None` while a
    /// worker runs the task.
    task: Option<Box<dyn Task>>,
    state: TaskState,
}

struct Inner {
    tasks: Mutex<HashMap<TaskId, Slot>>,
    /// Signalled (with `tasks`) whenever a task finishes.
    done_cv: Condvar,
    runq: Mutex<VecDeque<TaskId>>,
    runq_cv: Condvar,
    /// Min-heap of `(deadline, task)` wake requests.  Entries are
    /// never cancelled: a stale deadline fires a spurious (harmless)
    /// wake instead of paying per-entry bookkeeping.
    timers: Mutex<BinaryHeap<Reverse<(Instant, TaskId)>>>,
    timers_cv: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicU64,
}

impl Inner {
    /// Lock order everywhere: `tasks` before `runq` before `timers`
    /// (each may be taken alone).
    fn wake(&self, id: TaskId) {
        let mut tasks = self.tasks.lock().unwrap();
        let Some(slot) = tasks.get_mut(&id) else { return };
        match slot.state {
            TaskState::Idle => {
                slot.state = TaskState::Queued;
                drop(tasks);
                self.enqueue(id);
            }
            TaskState::Running => slot.state = TaskState::RunningDirty,
            TaskState::Queued | TaskState::RunningDirty => {}
        }
    }

    fn enqueue(&self, id: TaskId) {
        self.runq.lock().unwrap().push_back(id);
        self.runq_cv.notify_one();
    }

    /// Restore a polled task into its slot per `outcome` (never
    /// [`PollOutcome::Done`] here).
    fn park(&self, id: TaskId, task: Box<dyn Task>, outcome: PollOutcome) {
        let mut requeue = false;
        let mut timer = None;
        {
            let mut tasks = self.tasks.lock().unwrap();
            let Some(slot) = tasks.get_mut(&id) else { return };
            let dirty = slot.state == TaskState::RunningDirty;
            slot.task = Some(task);
            match outcome {
                PollOutcome::Yield => {
                    slot.state = TaskState::Queued;
                    requeue = true;
                }
                PollOutcome::Pending(deadline) => {
                    if dirty {
                        // A wake landed mid-poll: the task must run
                        // again or the wake would be lost.
                        slot.state = TaskState::Queued;
                        requeue = true;
                    } else {
                        slot.state = TaskState::Idle;
                        timer = deadline;
                    }
                }
                PollOutcome::Done => unreachable!("Done is handled by the worker"),
            }
        }
        if requeue {
            self.enqueue(id);
        }
        if let Some(at) = timer {
            self.timers.lock().unwrap().push(Reverse((at, id)));
            self.timers_cv.notify_one();
        }
    }

    fn worker_loop(&self) {
        loop {
            let id = {
                let mut q = self.runq.lock().unwrap();
                loop {
                    if let Some(id) = q.pop_front() {
                        break id;
                    }
                    if self.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    q = self.runq_cv.wait(q).unwrap();
                }
            };
            let task = {
                let mut tasks = self.tasks.lock().unwrap();
                match tasks.get_mut(&id) {
                    Some(slot) => {
                        slot.state = TaskState::Running;
                        slot.task.take()
                    }
                    None => None,
                }
            };
            let Some(mut task) = task else { continue };
            // A panicking task is finished (the pre-reactor analogue:
            // its thread died); it must not wedge the worker or leave
            // a slot that `wait_done` waits on forever.
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task.poll()))
                    .unwrap_or(PollOutcome::Done);
            if outcome == PollOutcome::Done {
                // Drop the task *before* removing its slot: `wait_done`
                // returning must mean the task's resources (files, GC
                // threads) are released — a caller may reopen its data
                // directory immediately.  The slot is inert meanwhile
                // (not queued; a late wake just marks it dirty).
                drop(task);
                self.tasks.lock().unwrap().remove(&id);
                self.done_cv.notify_all();
            } else {
                self.park(id, task, outcome);
            }
        }
    }

    fn timer_loop(&self) {
        let mut timers = self.timers.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            let now = Instant::now();
            let mut due = Vec::new();
            while let Some(&Reverse((at, id))) = timers.peek() {
                if at > now {
                    break;
                }
                timers.pop();
                due.push(id);
            }
            if !due.is_empty() {
                drop(timers);
                for id in due {
                    self.wake(id);
                }
                timers = self.timers.lock().unwrap();
                continue;
            }
            timers = match timers.peek() {
                Some(&Reverse((at, _))) => {
                    let wait = at.saturating_duration_since(now);
                    self.timers_cv.wait_timeout(timers, wait).unwrap().0
                }
                None => self.timers_cv.wait(timers).unwrap(),
            };
        }
    }
}

/// Cloneable wake/spawn handle onto a running [`Reactor`] (what
/// mailbox doorbells and apply lanes capture).
#[derive(Clone)]
pub struct ReactorHandle {
    inner: Arc<Inner>,
}

impl ReactorHandle {
    pub fn wake(&self, id: TaskId) {
        self.inner.wake(id);
    }
}

/// The worker pool.  Dropping (or [`Reactor::shutdown`]) stops the
/// workers; tasks still registered are dropped on the caller's thread.
pub struct Reactor {
    inner: Arc<Inner>,
    threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// Worker-pool size for this host: every core up to 8, but always at
/// least 2 so one long poll cannot starve the whole process.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map_or(2, |n| n.get()).clamp(2, 8)
}

impl Reactor {
    pub fn new(workers: usize) -> Self {
        let inner = Arc::new(Inner {
            tasks: Mutex::new(HashMap::new()),
            done_cv: Condvar::new(),
            runq: Mutex::new(VecDeque::new()),
            runq_cv: Condvar::new(),
            timers: Mutex::new(BinaryHeap::new()),
            timers_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
        });
        let mut threads = Vec::with_capacity(workers + 1);
        for i in 0..workers.max(1) {
            let inner2 = Arc::clone(&inner);
            let t = std::thread::Builder::new()
                .name(format!("nezha-reactor-{i}"))
                .spawn(move || inner2.worker_loop())
                .expect("spawn reactor worker");
            threads.push(t);
        }
        let inner2 = Arc::clone(&inner);
        threads.push(
            std::thread::Builder::new()
                .name("nezha-reactor-timer".into())
                .spawn(move || inner2.timer_loop())
                .expect("spawn reactor timer"),
        );
        Self { inner, threads: Mutex::new(threads) }
    }

    pub fn handle(&self) -> ReactorHandle {
        ReactorHandle { inner: Arc::clone(&self.inner) }
    }

    /// Worker count (excludes the timer thread).
    pub fn workers(&self) -> usize {
        self.threads.lock().unwrap().len().saturating_sub(1)
    }

    /// Register a task and queue its first poll.
    pub fn spawn(&self, task: Box<dyn Task>) -> TaskId {
        let id = self.inner.next_id.fetch_add(1, Ordering::Relaxed);
        self.inner
            .tasks
            .lock()
            .unwrap()
            .insert(id, Slot { task: Some(task), state: TaskState::Queued });
        self.inner.enqueue(id);
        id
    }

    pub fn wake(&self, id: TaskId) {
        self.inner.wake(id);
    }

    /// Block until task `id` finishes (true) or `timeout` lapses
    /// (false).  An unknown id reads as already finished.
    pub fn wait_done(&self, id: TaskId, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut tasks = self.inner.tasks.lock().unwrap();
        while tasks.contains_key(&id) {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            tasks = self.inner.done_cv.wait_timeout(tasks, deadline - now).unwrap().0;
        }
        true
    }

    /// Stop the workers and timer (idempotent).  Registered tasks are
    /// dropped here, on the caller's thread.
    pub fn shutdown(&self) {
        self.inner.shutdown.store(true, Ordering::Release);
        self.inner.runq_cv.notify_all();
        self.inner.timers_cv.notify_all();
        let threads = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
        self.inner.tasks.lock().unwrap().clear();
        self.inner.done_cv.notify_all();
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Polls `yields + 1` times (counting), then finishes.
    struct Counter {
        n: Arc<AtomicUsize>,
        yields: usize,
    }

    impl Task for Counter {
        fn poll(&mut self) -> PollOutcome {
            self.n.fetch_add(1, Ordering::SeqCst);
            if self.yields > 0 {
                self.yields -= 1;
                PollOutcome::Yield
            } else {
                PollOutcome::Done
            }
        }
    }

    #[test]
    fn yielding_tasks_all_complete_on_a_small_pool() {
        let r = Reactor::new(2);
        let counts: Vec<Arc<AtomicUsize>> =
            (0..32).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let ids: Vec<TaskId> = counts
            .iter()
            .map(|n| r.spawn(Box::new(Counter { n: Arc::clone(n), yields: 10 })))
            .collect();
        for id in ids {
            assert!(r.wait_done(id, Duration::from_secs(10)), "task {id} never finished");
        }
        for n in &counts {
            assert_eq!(n.load(Ordering::SeqCst), 11);
        }
        r.shutdown();
    }

    /// Parks until woken; finishes on the second poll.
    struct WaitForWake {
        n: Arc<AtomicUsize>,
    }

    impl Task for WaitForWake {
        fn poll(&mut self) -> PollOutcome {
            if self.n.fetch_add(1, Ordering::SeqCst) == 0 {
                PollOutcome::Pending(None)
            } else {
                PollOutcome::Done
            }
        }
    }

    #[test]
    fn wake_repolls_a_parked_task() {
        let r = Reactor::new(2);
        let n = Arc::new(AtomicUsize::new(0));
        let id = r.spawn(Box::new(WaitForWake { n: Arc::clone(&n) }));
        // Wait out the first poll, then ring.
        let t0 = Instant::now();
        while n.load(Ordering::SeqCst) == 0 && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(n.load(Ordering::SeqCst), 1, "first poll parked");
        r.wake(id);
        assert!(r.wait_done(id, Duration::from_secs(5)));
        assert_eq!(n.load(Ordering::SeqCst), 2);
        r.shutdown();
    }

    /// Parks with a deadline; the timer must bring it back.
    struct Alarm {
        n: Arc<AtomicUsize>,
    }

    impl Task for Alarm {
        fn poll(&mut self) -> PollOutcome {
            if self.n.fetch_add(1, Ordering::SeqCst) == 0 {
                PollOutcome::Pending(Some(Instant::now() + Duration::from_millis(20)))
            } else {
                PollOutcome::Done
            }
        }
    }

    #[test]
    fn timer_deadline_wakes_a_parked_task() {
        let r = Reactor::new(2);
        let n = Arc::new(AtomicUsize::new(0));
        let id = r.spawn(Box::new(Alarm { n: Arc::clone(&n) }));
        assert!(r.wait_done(id, Duration::from_secs(5)), "deadline never fired");
        assert_eq!(n.load(Ordering::SeqCst), 2);
        r.shutdown();
    }

    struct Panicker;

    impl Task for Panicker {
        fn poll(&mut self) -> PollOutcome {
            panic!("task blew up");
        }
    }

    #[test]
    fn panicking_task_reads_done_and_pool_survives() {
        let r = Reactor::new(2);
        let id = r.spawn(Box::new(Panicker));
        assert!(r.wait_done(id, Duration::from_secs(5)));
        // Pool still serves new tasks afterwards.
        let n = Arc::new(AtomicUsize::new(0));
        let id2 = r.spawn(Box::new(Counter { n: Arc::clone(&n), yields: 0 }));
        assert!(r.wait_done(id2, Duration::from_secs(5)));
        assert_eq!(n.load(Ordering::SeqCst), 1);
        r.shutdown();
    }

    #[test]
    fn wait_done_times_out_on_a_sleeping_task() {
        let r = Reactor::new(1);
        let n = Arc::new(AtomicUsize::new(0));
        let id = r.spawn(Box::new(WaitForWake { n }));
        assert!(!r.wait_done(id, Duration::from_millis(50)), "parked task reported done");
        r.shutdown();
    }

    #[test]
    fn default_workers_is_small_but_plural() {
        let w = default_workers();
        assert!((2..=8).contains(&w), "w={w}");
    }
}
