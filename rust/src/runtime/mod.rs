//! PJRT runtime: load the AOT-compiled JAX/Pallas `index_build` module
//! (HLO text emitted by `python/compile/aot.py`) and run it from the
//! GC path when constructing the Final Compacted Storage hash index.
//!
//! Python never runs here — the artifact was lowered once at build
//! time (`make artifacts`); this module compiles the HLO text with the
//! PJRT CPU client and executes it with concrete key batches.
//!
//! The wiring follows /opt/xla-example/load_hlo: HLO **text** (not a
//! serialized proto) is the interchange format because jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects.
//!
//! [`reactor`] is the other half of this module: the event-driven
//! worker pool the coordinator schedules replica tasks on.

pub mod reactor;

use crate::gc::IndexBackend;
use crate::vlog::hash::{canonicalize, KEY_WORDS};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Fixed batch the artifact was specialized to (see
/// `python/compile/aot.py::BATCH` and `artifacts/manifest.json`).
pub const BATCH: usize = 4096;

/// Probes per key (python `model.BLOOM_K`).
pub const BLOOM_K: usize = 4;

/// Default artifact location relative to the repo root.
pub fn default_artifact() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/index_build.hlo.txt")
}

/// One batch's outputs.
#[derive(Debug)]
pub struct PlanBatch {
    pub h1: Vec<u32>,
    pub h2: Vec<u32>,
    pub bucket: Vec<u32>,
    /// Row-major `[n][BLOOM_K]` bloom bit positions.
    pub bloom_pos: Vec<u32>,
}

/// The XLA-backed index planner (L2 graph, containing the L1 Pallas
/// kernel) — implements [`IndexBackend`] for the GC framework.
pub struct IndexPlanner {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    batch: usize,
}

// The xla crate handles are thread-confined by default; we serialize
// access through the Mutex above.
unsafe impl Send for IndexPlanner {}
unsafe impl Sync for IndexPlanner {}

impl IndexPlanner {
    /// Compile the HLO artifact on the PJRT CPU client.
    pub fn load(path: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;
        Ok(Self { exe: Mutex::new(exe), batch: BATCH })
    }

    /// Load from the default artifacts directory if present.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifact())
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Run one padded batch through the compiled module.
    fn run_batch(
        &self,
        words: &[u32],
        lens: &[u32],
        n_buckets: u32,
        bloom_mask: u32,
    ) -> Result<PlanBatch> {
        debug_assert_eq!(words.len(), self.batch * KEY_WORDS);
        debug_assert_eq!(lens.len(), self.batch);
        let words_lit = xla::Literal::vec1(words).reshape(&[self.batch as i64, KEY_WORDS as i64])?;
        let lens_lit = xla::Literal::vec1(lens);
        let nb = xla::Literal::scalar(n_buckets);
        let bm = xla::Literal::scalar(bloom_mask);
        let exe = self.exe.lock().unwrap();
        let result = exe.execute::<xla::Literal>(&[words_lit, lens_lit, nb, bm])?[0][0]
            .to_literal_sync()?;
        drop(exe);
        // aot.py lowers with return_tuple=True: 4-tuple of u32 arrays.
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        Ok(PlanBatch {
            h1: parts[0].to_vec::<u32>()?,
            h2: parts[1].to_vec::<u32>()?,
            bucket: parts[2].to_vec::<u32>()?,
            bloom_pos: parts[3].to_vec::<u32>()?,
        })
    }

    /// Plan an arbitrary number of keys (pads the final batch).
    pub fn plan_keys(&self, keys: &[&[u8]], n_buckets: u32, bloom_mask: u32) -> Result<PlanBatch> {
        let n = keys.len();
        let mut h1 = Vec::with_capacity(n);
        let mut h2 = Vec::with_capacity(n);
        let mut bucket = Vec::with_capacity(n);
        let mut bloom = Vec::with_capacity(n * BLOOM_K);
        let mut words = vec![0u32; self.batch * KEY_WORDS];
        let mut lens = vec![0u32; self.batch];
        for chunk in keys.chunks(self.batch) {
            words.iter_mut().for_each(|w| *w = 0);
            lens.iter_mut().for_each(|l| *l = 0);
            for (i, k) in chunk.iter().enumerate() {
                let (w, l) = canonicalize(k);
                words[i * KEY_WORDS..(i + 1) * KEY_WORDS].copy_from_slice(&w);
                lens[i] = l;
            }
            let out = self.run_batch(&words, &lens, n_buckets, bloom_mask)?;
            h1.extend_from_slice(&out.h1[..chunk.len()]);
            h2.extend_from_slice(&out.h2[..chunk.len()]);
            bucket.extend_from_slice(&out.bucket[..chunk.len()]);
            bloom.extend_from_slice(&out.bloom_pos[..chunk.len() * BLOOM_K]);
        }
        Ok(PlanBatch { h1, h2, bucket, bloom_pos: bloom })
    }
}

impl IndexBackend for IndexPlanner {
    fn plan(&self, keys: &[&[u8]], n_buckets: u32) -> Result<(Vec<u32>, Vec<u32>)> {
        let out = self.plan_keys(keys, n_buckets.max(1), 0)?;
        Ok((out.h1, out.bucket))
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vlog::hash::hash_pair;

    fn planner() -> Option<IndexPlanner> {
        let p = default_artifact();
        if !p.exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(IndexPlanner::load(&p).expect("load artifact"))
    }

    #[test]
    fn xla_matches_rust_hash_bit_for_bit() {
        let Some(pl) = planner() else { return };
        let keys: Vec<Vec<u8>> = (0..300u32)
            .map(|i| format!("user{i:08}").into_bytes())
            .chain([b"".to_vec(), b"a".to_vec(), vec![0xffu8; 32]])
            .collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_slice()).collect();
        let out = pl.plan_keys(&refs, 1021, (1 << 16) - 1).unwrap();
        for (i, k) in refs.iter().enumerate() {
            let (h1, h2) = hash_pair(k);
            assert_eq!(out.h1[i], h1, "h1 mismatch for {k:?}");
            assert_eq!(out.h2[i], h2, "h2 mismatch for {k:?}");
            assert_eq!(out.bucket[i], h1 % 1021);
            for j in 0..BLOOM_K {
                let want = h1.wrapping_add((j as u32).wrapping_mul(h2)) & ((1 << 16) - 1);
                assert_eq!(out.bloom_pos[i * BLOOM_K + j], want);
            }
        }
    }

    #[test]
    fn padding_does_not_leak_between_batches() {
        let Some(pl) = planner() else { return };
        // A batch of 1 and a batch of BATCH+1 must agree on shared keys.
        let single: Vec<&[u8]> = vec![b"shared-key"];
        let a = pl.plan_keys(&single, 64, 255).unwrap();
        let many_owned: Vec<Vec<u8>> = (0..BATCH + 1)
            .map(|i| if i == 0 { b"shared-key".to_vec() } else { format!("k{i}").into_bytes() })
            .collect();
        let many: Vec<&[u8]> = many_owned.iter().map(|k| k.as_slice()).collect();
        let b = pl.plan_keys(&many, 64, 255).unwrap();
        assert_eq!(a.h1[0], b.h1[0]);
        assert_eq!(a.bucket[0], b.bucket[0]);
        assert_eq!(b.h1.len(), BATCH + 1);
    }
}
