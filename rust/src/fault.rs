//! Deterministic fault injection: the nemesis substrate.
//!
//! [`FaultPlan`] is a runtime-mutable description of injected network
//! faults — symmetric and one-way **partitions**, message
//! **duplication**, **reordering** jitter, and per-link latency/loss
//! overrides — shared by every transport of one cluster
//! ([`crate::raft::Bus`], [`crate::raft::SimNet`], and best-effort
//! [`crate::raft::TcpNet`]).  All randomness comes from one seeded
//! [`Rng`], so a `(seed, plan-mutation sequence, decide sequence)`
//! triple replays byte-identically: the determinism regression test in
//! `raft::transport` holds the whole stack to that.
//!
//! [`disk`] is the storage-side counterpart: arm an injected failure
//! for the Nth fsync/write whose path matches a set of substrings
//! (raft log, vlog, LEVELS manifest), then crash-restart the node and
//! assert the GC commit-point ordering recovers.  Hooks live in
//! `vlog::log::VLog::sync`/`flush_buf`, `gc::levels::save_framed`, and
//! `vlog::sorted::SortedVLogWriter::finish` (the seal fsync of every
//! sorted-run output, so a fault can land inside one partition of a
//! parallel merge) — every durability decision in the tree funnels
//! through those.
//!
//! Neither side is compiled out in release builds: an inert plan is a
//! single relaxed atomic load on the send path and the disk registry a
//! single atomic load per sync, so the production cost is negligible
//! and chaos tests exercise the exact shipping code.

use crate::raft::NodeId;
use crate::util::Rng;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Per-link overrides, applied to frames from one ordered `(from, to)`
/// pair.  `None` fields keep the transport's configured behaviour.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkFault {
    /// Replace the configured one-way latency range (µs, inclusive lo,
    /// exclusive hi+1 — same convention as [`crate::raft::NetConfig`]).
    pub latency_us: Option<(u64, u64)>,
    /// Replace the configured loss probability.
    pub loss: Option<f64>,
}

/// The verdict [`FaultPlan::decide`] hands a transport for one frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Delivery {
    /// Extra per-copy delay in µs; one entry per copy to deliver
    /// (duplication injects a second entry, reordering a non-zero
    /// delay).  **Empty means the fault plan dropped the frame.**
    pub copies: Vec<u64>,
    /// Per-link latency override to use instead of the configured
    /// range, if one is set.
    pub latency_us: Option<(u64, u64)>,
}

impl Delivery {
    pub fn dropped(&self) -> bool {
        self.copies.is_empty()
    }
}

#[derive(Debug)]
struct PlanState {
    rng: Rng,
    /// Symmetric partitions: both directions blocked.
    cuts: Vec<(NodeId, NodeId)>,
    /// One-way partitions: only `from → to` blocked.
    one_way: Vec<(NodeId, NodeId)>,
    links: HashMap<(NodeId, NodeId), LinkFault>,
    /// Probability a frame is delivered twice.
    dup: f64,
    /// Probability a frame is delayed by up to `reorder_window_us`,
    /// letting later frames overtake it.
    reorder: f64,
    reorder_window_us: u64,
}

impl PlanState {
    fn blocked(&self, from: NodeId, to: NodeId) -> bool {
        self.one_way.contains(&(from, to))
            || self.cuts.iter().any(|&(a, b)| (a == from && b == to) || (a == to && b == from))
    }

    fn any_fault(&self) -> bool {
        !self.cuts.is_empty()
            || !self.one_way.is_empty()
            || !self.links.is_empty()
            || self.dup > 0.0
            || self.reorder > 0.0
    }
}

/// Runtime-mutable, deterministic network fault plan.  Cheap to share
/// (`Arc<FaultPlan>`), cheap when inert (one relaxed load per send).
#[derive(Debug)]
pub struct FaultPlan {
    /// Fast path: false ⇒ `decide` returns `None` without locking.
    active: AtomicBool,
    inner: Mutex<PlanState>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        Self {
            active: AtomicBool::new(false),
            inner: Mutex::new(PlanState {
                rng: Rng::new(seed),
                cuts: Vec::new(),
                one_way: Vec::new(),
                links: HashMap::new(),
                dup: 0.0,
                reorder: 0.0,
                reorder_window_us: 0,
            }),
        }
    }

    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Relaxed)
    }

    fn mutate(&self, f: impl FnOnce(&mut PlanState)) {
        let mut st = self.inner.lock().unwrap();
        f(&mut st);
        self.active.store(st.any_fault(), Ordering::Relaxed);
    }

    /// Block all traffic between `a` and `b` (both directions).
    pub fn partition(&self, a: NodeId, b: NodeId) {
        self.mutate(|st| st.cuts.push((a, b)));
    }

    /// Block only `from → to`; the reverse direction still flows (the
    /// asymmetric-link shape that breaks naive leader leases).
    pub fn partition_one_way(&self, from: NodeId, to: NodeId) {
        self.mutate(|st| st.one_way.push((from, to)));
    }

    /// Cut `id` off from every listed peer, both directions.
    pub fn isolate(&self, id: NodeId, peers: &[NodeId]) {
        self.mutate(|st| {
            for &p in peers {
                if p != id {
                    st.cuts.push((id, p));
                }
            }
        });
    }

    /// Remove every partition (symmetric and one-way).  Duplication,
    /// reordering, and link overrides stay armed — use
    /// [`Self::clear`] for a full reset.
    pub fn heal(&self) {
        self.mutate(|st| {
            st.cuts.clear();
            st.one_way.clear();
        });
    }

    /// Deliver a fraction `p` of frames twice.
    pub fn set_duplication(&self, p: f64) {
        self.mutate(|st| st.dup = p.clamp(0.0, 1.0));
    }

    /// Delay a fraction `p` of frames by up to `window_us`, letting
    /// later frames overtake them.
    pub fn set_reorder(&self, p: f64, window_us: u64) {
        self.mutate(|st| {
            st.reorder = p.clamp(0.0, 1.0);
            st.reorder_window_us = window_us;
        });
    }

    /// Override one ordered link's latency/loss.
    pub fn set_link(&self, from: NodeId, to: NodeId, fault: LinkFault) {
        self.mutate(|st| {
            st.links.insert((from, to), fault);
        });
    }

    pub fn clear_link(&self, from: NodeId, to: NodeId) {
        self.mutate(|st| {
            st.links.remove(&(from, to));
        });
    }

    /// Full reset: no partitions, no dup/reorder, no link overrides.
    pub fn clear(&self) {
        self.mutate(|st| {
            st.cuts.clear();
            st.one_way.clear();
            st.links.clear();
            st.dup = 0.0;
            st.reorder = 0.0;
            st.reorder_window_us = 0;
        });
    }

    pub fn is_blocked(&self, from: NodeId, to: NodeId) -> bool {
        self.is_active() && self.inner.lock().unwrap().blocked(from, to)
    }

    /// The transport-facing entry point: decide the fate of one frame.
    /// `None` means "no plan active, use the configured behaviour" —
    /// the inert fast path.  RNG draws happen in a fixed order (loss,
    /// dup, per-copy reorder), so identical plan/decide sequences
    /// replay identically.
    pub fn decide(&self, from: NodeId, to: NodeId) -> Option<Delivery> {
        if !self.is_active() {
            return None;
        }
        let mut st = self.inner.lock().unwrap();
        if st.blocked(from, to) {
            return Some(Delivery { copies: Vec::new(), latency_us: None });
        }
        let link = st.links.get(&(from, to)).copied().unwrap_or_default();
        if let Some(p) = link.loss {
            if p > 0.0 && st.rng.chance(p) {
                return Some(Delivery { copies: Vec::new(), latency_us: link.latency_us });
            }
        }
        let n = if st.dup > 0.0 && st.rng.chance(st.dup) { 2 } else { 1 };
        let mut copies = Vec::with_capacity(n);
        for _ in 0..n {
            let extra = if st.reorder > 0.0 && st.rng.chance(st.reorder) {
                st.rng.below(st.reorder_window_us.max(1) + 1)
            } else {
                0
            };
            copies.push(extra);
        }
        Some(Delivery { copies, latency_us: link.latency_us })
    }
}

// ---------------------------------------------------------------------
// Disk faults
// ---------------------------------------------------------------------

/// Injected storage failures: fail the Nth fsync/write whose path
/// matches every armed substring.  Global (one registry per process)
/// because the durability hooks sit deep under `VLog`/`save_framed`
/// where no handle can be threaded through; tests scope their patterns
/// with unique temp-dir components so parallel tests cannot cross-fire.
pub mod disk {
    use anyhow::{bail, Result};
    use std::path::Path;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Mutex, OnceLock};

    /// Which durability operation an armed fault targets.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum DiskOp {
        /// `sync_data`-class commit points (vlog/raft-log fsync, the
        /// framed-manifest rename barrier).
        Sync,
        /// Buffered payload writes ahead of the sync.
        Write,
    }

    #[derive(Debug)]
    struct Armed {
        substrs: Vec<String>,
        op: DiskOp,
        /// Fires (and disarms) when this reaches zero.
        remaining: u64,
    }

    static ACTIVE: AtomicBool = AtomicBool::new(false);
    static FIRED: AtomicU64 = AtomicU64::new(0);

    fn registry() -> &'static Mutex<Vec<Armed>> {
        static R: OnceLock<Mutex<Vec<Armed>>> = OnceLock::new();
        R.get_or_init(|| Mutex::new(Vec::new()))
    }

    /// Arm one fault: the `nth` (1-based) `op` on a path containing
    /// **every** substring in `substrs` fails with an injected error,
    /// then the fault disarms itself.
    pub fn arm(substrs: &[impl AsRef<str>], op: DiskOp, nth: u64) {
        let mut reg = registry().lock().unwrap();
        reg.push(Armed {
            substrs: substrs.iter().map(|s| s.as_ref().to_string()).collect(),
            op,
            remaining: nth.max(1),
        });
        ACTIVE.store(true, Ordering::Release);
    }

    /// Disarm everything (fired or not).
    pub fn clear() {
        let mut reg = registry().lock().unwrap();
        reg.clear();
        ACTIVE.store(false, Ordering::Release);
    }

    /// Total injected failures since process start.
    pub fn fired() -> u64 {
        FIRED.load(Ordering::Relaxed)
    }

    /// Armed (not yet fired) fault count.
    pub fn pending() -> usize {
        if !ACTIVE.load(Ordering::Acquire) {
            return 0;
        }
        registry().lock().unwrap().len()
    }

    /// The hook the storage layer calls before committing `op` on
    /// `path`.  Inert unless something is armed (one atomic load).
    pub fn check(path: &Path, op: DiskOp) -> Result<()> {
        if !ACTIVE.load(Ordering::Acquire) {
            return Ok(());
        }
        let p = path.to_string_lossy();
        let mut reg = registry().lock().unwrap();
        let hit = reg
            .iter()
            .position(|a| a.op == op && a.substrs.iter().all(|s| p.contains(s.as_str())));
        if let Some(i) = hit {
            reg[i].remaining -= 1;
            if reg[i].remaining == 0 {
                reg.remove(i);
                if reg.is_empty() {
                    ACTIVE.store(false, Ordering::Release);
                }
                FIRED.fetch_add(1, Ordering::Relaxed);
                bail!("injected disk fault: {op:?} on {p}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_plan_decides_nothing() {
        let plan = FaultPlan::new(1);
        assert!(!plan.is_active());
        assert!(plan.decide(1, 2).is_none());
    }

    #[test]
    fn partition_blocks_both_ways_until_heal() {
        let plan = FaultPlan::new(2);
        plan.partition(1, 2);
        assert!(plan.decide(1, 2).unwrap().dropped());
        assert!(plan.decide(2, 1).unwrap().dropped());
        assert!(!plan.decide(1, 3).unwrap().dropped());
        plan.heal();
        assert!(!plan.is_active());
        assert!(plan.decide(1, 2).is_none());
    }

    #[test]
    fn one_way_partition_is_asymmetric() {
        let plan = FaultPlan::new(3);
        plan.partition_one_way(1, 2);
        assert!(plan.decide(1, 2).unwrap().dropped());
        assert!(!plan.decide(2, 1).unwrap().dropped());
    }

    #[test]
    fn isolate_cuts_every_listed_peer() {
        let plan = FaultPlan::new(4);
        plan.isolate(2, &[1, 2, 3]);
        assert!(plan.decide(2, 1).unwrap().dropped());
        assert!(plan.decide(3, 2).unwrap().dropped());
        assert!(!plan.decide(1, 3).unwrap().dropped());
    }

    #[test]
    fn duplication_and_reorder_emit_extra_copies_and_delays() {
        let plan = FaultPlan::new(5);
        plan.set_duplication(1.0);
        plan.set_reorder(1.0, 500);
        let d = plan.decide(1, 2).unwrap();
        assert_eq!(d.copies.len(), 2);
        assert!(d.copies.iter().all(|&c| c <= 500));
    }

    #[test]
    fn link_overrides_apply_per_direction() {
        let plan = FaultPlan::new(6);
        plan.set_link(1, 2, LinkFault { latency_us: Some((10, 20)), loss: Some(1.0) });
        assert!(plan.decide(1, 2).unwrap().dropped());
        let rev = plan.decide(2, 1).unwrap();
        assert!(!rev.dropped());
        assert_eq!(rev.latency_us, None);
        plan.clear_link(1, 2);
        assert!(plan.decide(1, 2).is_none(), "clearing the only fault deactivates the plan");
    }

    #[test]
    fn decide_sequence_replays_per_seed() {
        let run = |seed| {
            let plan = FaultPlan::new(seed);
            plan.set_duplication(0.3);
            plan.set_reorder(0.4, 1000);
            plan.set_link(1, 2, LinkFault { latency_us: None, loss: Some(0.5) });
            let mut out = Vec::new();
            for i in 0..200u64 {
                let (from, to) = (1 + i % 3, 1 + (i + 1) % 3);
                out.push(plan.decide(from, to));
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn disk_fault_fires_on_nth_match_then_disarms() {
        use disk::DiskOp;
        let tag = format!("fault-unit-{}", std::process::id());
        let path = std::path::PathBuf::from(format!("/tmp/{tag}/node-1/engine/LEVELS"));
        disk::arm(&[tag.as_str(), "LEVELS"], DiskOp::Sync, 2);
        assert!(disk::check(&path, DiskOp::Write).is_ok(), "op kind must match");
        assert!(disk::check(&path, DiskOp::Sync).is_ok(), "first match survives (nth=2)");
        let before = disk::fired();
        assert!(disk::check(&path, DiskOp::Sync).is_err(), "second match fails");
        assert_eq!(disk::fired(), before + 1);
        assert!(disk::check(&path, DiskOp::Sync).is_ok(), "fault disarmed after firing");
        disk::clear();
    }

    #[test]
    fn disk_fault_requires_every_substring() {
        use disk::DiskOp;
        let tag = format!("fault-scope-{}", std::process::id());
        disk::arm(&[tag.as_str(), "node-2", "raft"], DiskOp::Sync, 1);
        let other = std::path::PathBuf::from(format!("/tmp/{tag}/node-1/raft/epoch-0"));
        assert!(disk::check(&other, DiskOp::Sync).is_ok(), "node-1 must not trip node-2's fault");
        let target = std::path::PathBuf::from(format!("/tmp/{tag}/node-2/raft/epoch-0"));
        assert!(disk::check(&target, DiskOp::Sync).is_err());
        disk::clear();
    }
}
