//! YCSB workload generator (Table II of the paper).
//!
//! | Workload | Write type | Query type  | Mix                |
//! |----------|-----------|-------------|--------------------|
//! | Load     | Insert    | —           | insert only        |
//! | A        | Update    | Point       | 50% write 50% read |
//! | B        | Update    | Point       | 5% write 95% read  |
//! | C        | —         | Point       | read only          |
//! | D        | Insert    | Point       | 5% write 95% read  |
//! | E        | Insert    | Range       | 5% write 95% scan  |
//! | F        | RMW       | Point       | 50% write 50% read |
//!
//! Keys are zero-padded (`user<rank>`) so range scans are meaningful;
//! the request distribution is Zipf(0.99) like YCSB's default.

use crate::util::{Rng, Zipf};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    Load,
    A,
    B,
    C,
    D,
    E,
    F,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 6] = [
        WorkloadKind::A,
        WorkloadKind::B,
        WorkloadKind::C,
        WorkloadKind::D,
        WorkloadKind::E,
        WorkloadKind::F,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            WorkloadKind::Load => "Load",
            WorkloadKind::A => "A",
            WorkloadKind::B => "B",
            WorkloadKind::C => "C",
            WorkloadKind::D => "D",
            WorkloadKind::E => "E",
            WorkloadKind::F => "F",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Some(match s.to_ascii_uppercase().as_str() {
            "LOAD" => WorkloadKind::Load,
            "A" => WorkloadKind::A,
            "B" => WorkloadKind::B,
            "C" => WorkloadKind::C,
            "D" => WorkloadKind::D,
            "E" => WorkloadKind::E,
            "F" => WorkloadKind::F,
            _ => return None,
        })
    }

    /// (read, update, insert, scan, rmw) proportions.
    fn mix(&self) -> (f64, f64, f64, f64, f64) {
        match self {
            WorkloadKind::Load => (0.0, 0.0, 1.0, 0.0, 0.0),
            WorkloadKind::A => (0.5, 0.5, 0.0, 0.0, 0.0),
            WorkloadKind::B => (0.95, 0.05, 0.0, 0.0, 0.0),
            WorkloadKind::C => (1.0, 0.0, 0.0, 0.0, 0.0),
            WorkloadKind::D => (0.95, 0.0, 0.05, 0.0, 0.0),
            WorkloadKind::E => (0.0, 0.0, 0.05, 0.95, 0.0),
            WorkloadKind::F => (0.5, 0.0, 0.0, 0.0, 0.5),
        }
    }
}

/// One generated operation.
#[derive(Clone, Debug)]
pub enum Op {
    Read(Vec<u8>),
    Update(Vec<u8>, Vec<u8>),
    Insert(Vec<u8>, Vec<u8>),
    /// (start key, number of records)
    Scan(Vec<u8>, usize),
    /// Read-modify-write.
    Rmw(Vec<u8>, Vec<u8>),
}

impl Op {
    pub fn is_write(&self) -> bool {
        matches!(self, Op::Update(..) | Op::Insert(..) | Op::Rmw(..))
    }

    pub fn is_scan(&self) -> bool {
        matches!(self, Op::Scan(..))
    }
}

/// Workload generator state.
pub struct Generator {
    kind: WorkloadKind,
    rng: Rng,
    zipf: Zipf,
    /// Keyspace size (grows on insert).
    records: u64,
    value_size: usize,
    max_scan_len: usize,
    value_seed: u64,
}

pub const KEY_PREFIX: &str = "user";

/// Rank -> key. Zero-padded so lexicographic order == numeric order.
pub fn key_of(rank: u64) -> Vec<u8> {
    format!("{KEY_PREFIX}{rank:012}").into_bytes()
}

impl Generator {
    pub fn new(kind: WorkloadKind, records: u64, value_size: usize, seed: u64) -> Self {
        let records = records.max(1);
        Self {
            kind,
            rng: Rng::new(seed),
            zipf: Zipf::new(records, 0.99),
            records,
            value_size,
            max_scan_len: 100,
            value_seed: seed ^ 0xBEEF,
        }
    }

    pub fn with_scan_len(mut self, n: usize) -> Self {
        self.max_scan_len = n;
        self
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    /// Deterministic value for a key (cheap fill, compressible like
    /// YCSB's field payloads).
    pub fn value_for(&mut self, tag: u64) -> Vec<u8> {
        let mut v = vec![0u8; self.value_size];
        let mut s = self.value_seed ^ tag;
        // Fill sparsely: every 64th byte varies; rest constant. Fast
        // and stops trivial dedup.
        for (i, b) in v.iter_mut().enumerate().step_by(61) {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
            *b = (s >> 33) as u8 ^ i as u8;
        }
        v
    }

    fn hot_key(&mut self) -> Vec<u8> {
        let rank = self.zipf.sample(&mut self.rng);
        key_of(rank)
    }

    pub fn next_op(&mut self) -> Op {
        let (read, update, insert, scan, _rmw) = self.kind.mix();
        let x = self.rng.f64();
        if x < read {
            Op::Read(self.hot_key())
        } else if x < read + update {
            let k = self.hot_key();
            let tag = self.rng.next_u64();
            let v = self.value_for(tag);
            Op::Update(k, v)
        } else if x < read + update + insert {
            let rank = self.records;
            self.records += 1;
            // Keep the zipf head over the growing keyspace (cheap
            // approximation: rebuild every 64k inserts).
            if self.records % 65536 == 0 {
                self.zipf = Zipf::new(self.records, 0.99);
            }
            let v = self.value_for(rank);
            Op::Insert(key_of(rank), v)
        } else if x < read + update + insert + scan {
            let len = (self.rng.below(self.max_scan_len as u64) + 1) as usize;
            Op::Scan(self.hot_key(), len)
        } else {
            let k = self.hot_key();
            let tag = self.rng.next_u64();
            let v = self.value_for(tag);
            Op::Rmw(k, v)
        }
    }

    /// The full load sequence (insert-only).
    pub fn load_ops(
        records: u64,
        value_size: usize,
        seed: u64,
    ) -> impl Iterator<Item = (Vec<u8>, Vec<u8>)> {
        let mut g = Generator::new(WorkloadKind::Load, 1, value_size, seed);
        (0..records).map(move |r| (key_of(r), g.value_for(r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_order_is_lexicographic() {
        assert!(key_of(9) < key_of(10));
        assert!(key_of(999_999) < key_of(1_000_000));
    }

    #[test]
    fn mixes_sum_to_one() {
        for k in [
            WorkloadKind::Load,
            WorkloadKind::A,
            WorkloadKind::B,
            WorkloadKind::C,
            WorkloadKind::D,
            WorkloadKind::E,
            WorkloadKind::F,
        ] {
            let (r, u, i, s, m) = k.mix();
            assert!((r + u + i + s + m - 1.0).abs() < 1e-9, "{k:?}");
        }
    }

    #[test]
    fn workload_a_is_half_writes() {
        let mut g = Generator::new(WorkloadKind::A, 10_000, 64, 1);
        let writes = (0..10_000).filter(|_| g.next_op().is_write()).count();
        assert!((4_000..6_000).contains(&writes), "writes={writes}");
    }

    #[test]
    fn workload_c_is_read_only() {
        let mut g = Generator::new(WorkloadKind::C, 1_000, 64, 2);
        assert!((0..5_000).all(|_| !g.next_op().is_write()));
    }

    #[test]
    fn workload_e_scans_dominate() {
        let mut g = Generator::new(WorkloadKind::E, 1_000, 64, 3).with_scan_len(50);
        let mut scans = 0;
        for _ in 0..2_000 {
            match g.next_op() {
                Op::Scan(_, len) => {
                    scans += 1;
                    assert!((1..=50).contains(&len));
                }
                Op::Insert(..) => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(scans > 1_700, "scans={scans}");
    }

    #[test]
    fn inserts_extend_keyspace() {
        let mut g = Generator::new(WorkloadKind::D, 100, 16, 4);
        let before = g.records();
        let mut inserted = Vec::new();
        for _ in 0..2_000 {
            if let Op::Insert(k, _) = g.next_op() {
                inserted.push(k);
            }
        }
        assert!(g.records() > before);
        // Inserted keys are fresh and increasing.
        for w in inserted.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let ops1: Vec<String> = {
            let mut g = Generator::new(WorkloadKind::A, 1000, 32, 9);
            (0..50).map(|_| format!("{:?}", g.next_op())).collect()
        };
        let mut g = Generator::new(WorkloadKind::A, 1000, 32, 9);
        let ops2: Vec<String> = (0..50).map(|_| format!("{:?}", g.next_op())).collect();
        assert_eq!(ops1, ops2);
    }

    #[test]
    fn values_have_requested_size() {
        let mut g = Generator::new(WorkloadKind::A, 10, 16 << 10, 5);
        assert_eq!(g.value_for(3).len(), 16 << 10);
    }
}
