"""L2 compute graph: Nezha's GC index-build planner.

Given a batch of canonical key words, produce everything the Rust GC
path needs to build the Final Compacted Storage read structures in one
fused XLA module:

* ``h1, h2``        — the two hash streams (L1 Pallas kernel),
* ``bucket``        — open-addressing home slot, ``h1 % n_buckets``,
* ``bloom_pos``     — ``BLOOM_K`` bit positions via double hashing
                      ``(h1 + i*h2) & bloom_mask``.

``n_buckets`` and ``bloom_mask`` are runtime u32 scalars so a single
AOT-compiled executable serves every GC cycle regardless of table
sizing.  The batch dimension is fixed at AOT time (``aot.py``); the
Rust caller pads the final batch.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import hash_kernel

BLOOM_K = 4  # probes per key; mirrored in rust/src/vlog/bloom constants


def index_build(words, lens, n_buckets, bloom_mask):
    """words: u32[N,4], lens: u32[N], n_buckets/bloom_mask: u32 scalars.

    Returns (h1[N], h2[N], bucket[N], bloom_pos[N, BLOOM_K]) — all u32.
    """
    h1, h2 = hash_kernel.hash_pairs(words, lens)
    bucket = h1 % jnp.maximum(n_buckets, jnp.uint32(1))
    i = jnp.arange(BLOOM_K, dtype=jnp.uint32)
    bloom_pos = (h1[:, None] + i[None, :] * h2[:, None]) & bloom_mask
    return h1, h2, bucket, bloom_pos
