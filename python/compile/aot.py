"""AOT emitter: lower the L2 index-build graph to HLO *text* for the
Rust PJRT loader.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids
which xla_extension 0.5.1 (what the published ``xla`` 0.1.6 crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage (from the python/ directory, as the Makefile does):
    python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hash_kernel

# Fixed AOT batch size: the Rust caller pads the final batch to this.
BATCH = 4096


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_index_build(batch: int = BATCH):
    words = jax.ShapeDtypeStruct((batch, hash_kernel.KEY_WORDS), jnp.uint32)
    lens = jax.ShapeDtypeStruct((batch,), jnp.uint32)
    scalar = jax.ShapeDtypeStruct((), jnp.uint32)
    return jax.jit(model.index_build).lower(words, lens, scalar, scalar)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    text = to_hlo_text(lower_index_build(args.batch))
    hlo_path = os.path.join(args.out_dir, "index_build.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)

    # Manifest consumed by rust/src/runtime — records the shapes the
    # executable was specialized to.
    manifest = {
        "index_build": {
            "file": "index_build.hlo.txt",
            "batch": args.batch,
            "key_words": hash_kernel.KEY_WORDS,
            "bloom_k": model.BLOOM_K,
            "inputs": ["words u32[B,4]", "lens u32[B]",
                       "n_buckets u32[]", "bloom_mask u32[]"],
            "outputs": ["h1 u32[B]", "h2 u32[B]", "bucket u32[B]",
                        "bloom_pos u32[B,4]"],
        }
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(text)} chars to {hlo_path}")


if __name__ == "__main__":
    main()
