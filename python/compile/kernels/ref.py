"""Pure-jnp (and pure-python) correctness oracles for the Pallas hash
kernel.  ``hash_pairs_ref`` is the vectorized jnp oracle used by the
pytest allclose checks; ``hash_pairs_scalar`` is a from-first-principles
python-int implementation used to validate the oracle itself and to
emit golden vectors for the Rust parity test."""

from __future__ import annotations

import jax.numpy as jnp

from .hash_kernel import (
    FNV_OFFSET,
    FNV_PRIME,
    KEY_WORDS,
    SEED1,
    SEED2,
)

_M = 0xFFFFFFFF


def _u32(x):
    return jnp.asarray(x, dtype=jnp.uint32)


def hash_pairs_ref(words, lens):
    """Vectorized jnp reference, no pallas involved."""

    def fmix(h):
        h = h ^ (h >> 16)
        h = h * _u32(0x85EBCA6B)
        h = h ^ (h >> 13)
        h = h * _u32(0xC2B2AE35)
        h = h ^ (h >> 16)
        return h

    def fnv(seed):
        h = (_u32(FNV_OFFSET) ^ _u32(seed)) ^ lens
        for w in range(KEY_WORDS):
            h = (h ^ words[:, w]) * _u32(FNV_PRIME)
        return fmix(h)

    return fnv(SEED1), fnv(SEED2) | _u32(1)


def _fmix32_scalar(h: int) -> int:
    h &= _M
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _M
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _M
    h ^= h >> 16
    return h


def hash_pairs_scalar(key: bytes) -> tuple[int, int]:
    """Hash one raw key exactly as the Rust side does: canonicalize to
    4 LE u32 words from the first 16 bytes (zero padded), fold in the
    byte length, FNV-1a word-at-a-time, fmix32 finalize."""
    words, lens = canonicalize(key)
    out = []
    for seed in (SEED1, SEED2):
        h = (FNV_OFFSET ^ seed ^ lens) & _M
        for w in words:
            h = ((h ^ w) * FNV_PRIME) & _M
        out.append(_fmix32_scalar(h))
    return out[0], out[1] | 1


def canonicalize(key: bytes) -> tuple[list[int], int]:
    """Key bytes -> (4 LE u32 words of the zero-padded 16-byte prefix,
    original length)."""
    buf = (key[:16] + b"\x00" * 16)[:16]
    words = [
        int.from_bytes(buf[4 * i : 4 * i + 4], "little") for i in range(KEY_WORDS)
    ]
    return words, len(key) & _M
