"""L1 Pallas kernel: batched key hashing for Nezha's GC index build.

Nezha's Final Compacted Storage accelerates point lookups with a hash
index over the sorted ValueLog (paper §III-C).  Building that index for
millions of keys is the one data-parallel compute hot-spot in the GC
path, so it is the kernel we AOT-compile and call from the Rust
coordinator.

Hash design (must stay bit-identical to ``rust/src/vlog/hash.rs``):

* Keys are canonicalized by the caller to 4 little-endian u32 words
  (first 16 bytes of the key, zero padded) plus the original byte
  length.
* ``h = FNV1a32(words, seed ^ len)`` word-at-a-time, then murmur3's
  ``fmix32`` finalizer for avalanche.
* Two independent seeds give (h1, h2); h2 is forced odd so the
  double-hashing probe sequence ``h1 + i*h2`` cycles the full table.

All arithmetic is wrapping u32 — elementwise VPU work.  The kernel is
tiled over the batch dimension with a BlockSpec of ``(BLOCK, 4)`` key
words per step; see DESIGN.md §1 for the layer contract and the
real-TPU scale estimate.  ``interpret=True`` everywhere: the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# FNV-1a 32-bit parameters.
FNV_OFFSET = 0x811C9DC5
FNV_PRIME = 0x01000193
# Independent seeds for the two hash streams (arbitrary odd constants,
# mirrored in rust/src/vlog/hash.rs).
SEED1 = 0x0
SEED2 = 0x9747B28C

KEY_WORDS = 4  # 16-byte canonical key prefix as 4 u32 LE words
BLOCK = 512    # batch tile: BLOCK*4*4 B key words + 2*BLOCK*4 B out per step


def _u32(x):
    return jnp.asarray(x, dtype=jnp.uint32)


def _fmix32(h):
    """murmur3 finalizer — full avalanche on a u32 lane."""
    h = h ^ (h >> 16)
    h = h * _u32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * _u32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def _fnv1a_words(words, lens, seed):
    """Word-at-a-time FNV-1a over ``words[N, KEY_WORDS]`` with the key
    byte-length folded into the seed (distinguishes zero-padded
    prefixes of different lengths)."""
    h = (_u32(FNV_OFFSET) ^ _u32(seed)) ^ lens
    for w in range(KEY_WORDS):
        h = (h ^ words[:, w]) * _u32(FNV_PRIME)
    return _fmix32(h)


def _hash_block_kernel(words_ref, lens_ref, h1_ref, h2_ref):
    """Pallas kernel body: one (BLOCK, KEY_WORDS) tile -> two BLOCK-wide
    hash lanes.  Pure elementwise u32 ops; the grid pipeline streams
    tiles HBM->VMEM."""
    words = words_ref[...]
    lens = lens_ref[...]
    h1_ref[...] = _fnv1a_words(words, lens, SEED1)
    # Force h2 odd so double-hash probing is a full-cycle permutation of
    # any power-of-two table.
    h2_ref[...] = _fnv1a_words(words, lens, SEED2) | _u32(1)


@functools.partial(jax.jit, static_argnames=("block",))
def hash_pairs(words, lens, *, block=BLOCK):
    """Batched (h1, h2) for canonical key words.

    words: u32[N, KEY_WORDS]; lens: u32[N].  N is padded internally to a
    multiple of ``block`` so one compiled executable serves any batch.
    """
    n = words.shape[0]
    pad = (-n) % block
    if pad:
        words = jnp.pad(words, ((0, pad), (0, 0)))
        lens = jnp.pad(lens, ((0, pad),))
    padded_n = words.shape[0]
    grid = (padded_n // block,)

    h1, h2 = pl.pallas_call(
        _hash_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, KEY_WORDS), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded_n,), jnp.uint32),
            jax.ShapeDtypeStruct((padded_n,), jnp.uint32),
        ],
        interpret=True,
    )(words, lens)
    return h1[:n], h2[:n]
