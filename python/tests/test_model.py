"""L2 graph shape/semantics tests + AOT lowering smoke test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import hash_kernel, ref


def _batch(n, seed=0):
    rng = np.random.default_rng(seed)
    words = jnp.asarray(
        rng.integers(0, 2**32, size=(n, 4), dtype=np.uint32))
    lens = jnp.asarray(rng.integers(0, 32, size=(n,), dtype=np.uint32))
    return words, lens


def test_index_build_shapes():
    words, lens = _batch(4096)
    h1, h2, bucket, pos = model.index_build(
        words, lens, jnp.uint32(1021), jnp.uint32((1 << 16) - 1))
    assert h1.shape == (4096,)
    assert bucket.shape == (4096,)
    assert pos.shape == (4096, model.BLOOM_K)


def test_bucket_in_range():
    words, lens = _batch(1024, seed=1)
    nb = 977  # prime, non power of two
    _, _, bucket, _ = model.index_build(
        words, lens, jnp.uint32(nb), jnp.uint32(255))
    assert int(jnp.max(bucket)) < nb


def test_bloom_pos_masked():
    words, lens = _batch(1024, seed=2)
    mask = (1 << 12) - 1
    _, _, _, pos = model.index_build(
        words, lens, jnp.uint32(7), jnp.uint32(mask))
    assert int(jnp.max(pos)) <= mask


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 2**32 - 1), st.integers(0, 20))
def test_bloom_double_hash_sequence(nb, mexp):
    """bloom_pos[i] must equal (h1 + i*h2) & mask exactly (wrapping)."""
    words, lens = _batch(8, seed=nb & 0xFFFF)
    mask = (1 << (mexp % 21)) - 1 if mexp else 0
    h1, h2, _, pos = model.index_build(
        words, lens, jnp.uint32(nb), jnp.uint32(mask))
    h1 = np.asarray(h1).astype(np.uint64)
    h2 = np.asarray(h2).astype(np.uint64)
    for i in range(model.BLOOM_K):
        want = ((h1 + i * h2) & 0xFFFFFFFF) & mask
        np.testing.assert_array_equal(np.asarray(pos[:, i]).astype(np.uint64), want)


def test_zero_buckets_guarded():
    """n_buckets=0 must not emit a divide-by-zero (clamped to 1)."""
    words, lens = _batch(8, seed=9)
    _, _, bucket, _ = model.index_build(
        words, lens, jnp.uint32(0), jnp.uint32(0))
    assert int(jnp.max(bucket)) == 0


def test_aot_lowering_produces_hlo_text():
    from compile import aot
    text = aot.to_hlo_text(aot.lower_index_build(256))
    assert "HloModule" in text
    assert "u32[256,4]" in text.replace(" ", "")[:4000] or "u32[256,4]" in text


def test_golden_vectors_for_rust_parity():
    """Golden (key -> h1,h2) vectors; rust/src/vlog/hash.rs has the
    identical table — if either side changes, both tests fail."""
    golden = {
        b"": None, b"a": None, b"foo": None,
        b"user4928": None, b"0123456789abcdef": None,
        b"0123456789abcdefXYZ": None,
    }
    for k in list(golden):
        golden[k] = ref.hash_pairs_scalar(k)
    # Deterministic contract: recompute twice.
    for k, v in golden.items():
        assert ref.hash_pairs_scalar(k) == v
        w, l = ref.canonicalize(k)
        h1, h2 = hash_kernel.hash_pairs(
            jnp.asarray(np.array([w], dtype=np.uint32)),
            jnp.asarray(np.array([l], dtype=np.uint32)))
        assert (int(h1[0]), int(h2[0])) == v
