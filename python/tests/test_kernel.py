"""Kernel-vs-reference correctness: the CORE numeric signal for L1.

The Pallas kernel (interpret=True) must agree bit-for-bit with the
pure-jnp oracle and with the from-first-principles scalar python
implementation, across shapes, paddings, and raw key bytes (hypothesis
sweeps)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hash_kernel, ref


def rand_batch(rng, n):
    words = rng.integers(0, 2**32, size=(n, hash_kernel.KEY_WORDS), dtype=np.uint32)
    lens = rng.integers(0, 64, size=(n,), dtype=np.uint32)
    return jnp.asarray(words), jnp.asarray(lens)


@pytest.mark.parametrize("n", [1, 2, 7, 64, 511, 512, 513, 1000, 4096])
def test_kernel_matches_ref_shapes(n):
    rng = np.random.default_rng(n)
    words, lens = rand_batch(rng, n)
    h1, h2 = hash_kernel.hash_pairs(words, lens)
    r1, r2 = ref.hash_pairs_ref(words, lens)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(r1))
    np.testing.assert_array_equal(np.asarray(h2), np.asarray(r2))
    assert h1.shape == (n,) and h2.shape == (n,)


@pytest.mark.parametrize("block", [64, 128, 512])
def test_kernel_block_size_invariance(block):
    """Tiling must not change the numbers."""
    rng = np.random.default_rng(7)
    words, lens = rand_batch(rng, 777)
    h1a, h2a = hash_kernel.hash_pairs(words, lens, block=block)
    h1b, h2b = hash_kernel.hash_pairs(words, lens, block=hash_kernel.BLOCK)
    np.testing.assert_array_equal(np.asarray(h1a), np.asarray(h1b))
    np.testing.assert_array_equal(np.asarray(h2a), np.asarray(h2b))


def test_h2_always_odd():
    rng = np.random.default_rng(11)
    words, lens = rand_batch(rng, 2048)
    _, h2 = hash_kernel.hash_pairs(words, lens)
    assert bool((np.asarray(h2) & 1).all())


@settings(max_examples=200, deadline=None)
@given(st.binary(min_size=0, max_size=48))
def test_scalar_matches_vector_on_raw_keys(key):
    """Raw bytes -> canonical words -> kernel must equal the scalar
    python-int implementation (the contract the Rust side mirrors)."""
    words, length = ref.canonicalize(key)
    w = jnp.asarray(np.array([words], dtype=np.uint32))
    l = jnp.asarray(np.array([length], dtype=np.uint32))
    h1, h2 = hash_kernel.hash_pairs(w, l)
    s1, s2 = ref.hash_pairs_scalar(key)
    assert int(h1[0]) == s1
    assert int(h2[0]) == s2


@settings(max_examples=100, deadline=None)
@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=4, max_size=4),
    st.integers(0, 2**32 - 1),
)
def test_kernel_matches_ref_hypothesis(words, length):
    w = jnp.asarray(np.array([words], dtype=np.uint32))
    l = jnp.asarray(np.array([length], dtype=np.uint32))
    h1, h2 = hash_kernel.hash_pairs(w, l)
    r1, r2 = ref.hash_pairs_ref(w, l)
    assert int(h1[0]) == int(r1[0])
    assert int(h2[0]) == int(r2[0])


def test_distribution_quality():
    """Sanity: bucket assignment over a power-of-two table is roughly
    uniform (chi-square-ish bound, loose)."""
    rng = np.random.default_rng(3)
    n = 1 << 14
    words, lens = rand_batch(rng, n)
    h1, _ = hash_kernel.hash_pairs(words, lens)
    buckets = np.asarray(h1) % 256
    counts = np.bincount(buckets, minlength=256)
    expect = n / 256
    assert counts.min() > expect * 0.6
    assert counts.max() < expect * 1.4


def test_length_distinguishes_padded_prefixes():
    """'a' and 'a\\0' canonicalize to the same words but different
    lengths — the hashes must differ."""
    a1, a2 = ref.hash_pairs_scalar(b"a")
    b1, b2 = ref.hash_pairs_scalar(b"a\x00")
    assert (a1, a2) != (b1, b2)
